"""Observability benchmark: zero-cost tracing, overhead, accuracy.

Three sections, one JSON document:

  * ``zero_cost`` — the same fixed-seed fleet run three ways (no tracer
    / disabled tracer / enabled tracer); per-request completion traces
    must be **bit-identical** across all three (the tracer draws from
    its own RNG and never touches the event loop, so even *enabled*
    tracing cannot perturb the simulation);
  * ``overhead`` — the ``bench_scale``-style smoke fleet (plus
    ``beam_search``, the branching-DAG workload, at a trickle rate)
    with and without an installed tracer, interleaved best-of-N wall
    timing of ``loop.run`` only; enabled tracing must cost <= 5%;
  * ``accuracy`` — the steady-state pooled registry fleet with a
    tracer and a DriftMonitor: span-reconstructed per-(workflow, LLM)
    execution shares must land within 15% relative error of the
    deployed ``MergedPipeline``'s expected shares, the per-class
    critical-path breakdown must sum to measured end-to-end latency,
    and the monitor must corroborate the tracer's shares.

``--dump`` additionally writes the accuracy run's full tracer export
(sampled spans + metrics snapshot + Prometheus exposition) for
``tools/scepsy_report.py`` to render.
"""
from __future__ import annotations

import argparse
import json
import math
import time
from typing import Dict, Optional, Tuple

from benchmarks.common import run_metadata
from repro import hw
from repro.core.drift import DriftMonitor, expectation_from
from repro.core.pipeline import merge_pipelines
from repro.core.scepsy import build_pipeline
from repro.core.scheduler import Allocation, SchedulerConfig, schedule_multi
from repro.core.telemetry import StatsSink
from repro.obs import (Tracer, accuracy_report, expected_shares,
                       install_tracer)
from repro.serving.deploy import (pooled_fleet_routers,
                                  routers_from_allocations, tenant_routers)
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver

# the bench_scale smoke fleet plus beam_search: the branching-DAG
# workload runs through the same fleet path at a trickle rate (its
# per-request fan-out is 24-844 GEN calls, so a little rate is a lot
# of calls)
RATES: Dict[str, float] = {
    "react_agent": 16.0,
    "debate": 1.1,
    "rag_reranker": 0.9,
    "map_reduce": 0.5,
    "beam_search": 0.05,
}
REPLICAS: Dict[str, int] = {
    "react_agent": 6,
    "debate": 4,
    "rag_reranker": 8,
    "map_reduce": 8,
    "beam_search": 4,
}
TOTAL_RATE = sum(RATES.values())
MIX: Dict[str, float] = {k: v / TOTAL_RATE for k, v in RATES.items()}

ACCURACY_FLEET = (("react_agent", 0.5), ("map_reduce", 0.4), ("debate", 0.8))

OVERHEAD_GATE = 1.05
SHARE_GATE = 0.15
RESIDUAL_GATE = 1e-6


def _settings(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {"mode": "smoke", "overhead_requests": 10_000,
                "zero_cost_requests": 1_500, "overhead_trials": 3,
                "accuracy_requests": 120, "n_trace": 8,
                "profile_groups": 6, "sample_per_workflow": 64}
    if quick:
        return {"mode": "quick", "overhead_requests": 30_000,
                "zero_cost_requests": 3_000, "overhead_trials": 3,
                "accuracy_requests": 200, "n_trace": 12,
                "profile_groups": 10, "sample_per_workflow": 64}
    return {"mode": "full", "overhead_requests": 100_000,
            "zero_cost_requests": 6_000, "overhead_trials": 5,
            "accuracy_requests": 400, "n_trace": 30,
            "profile_groups": 30, "sample_per_workflow": 128}


# ---------------------------------------------------------------------------
# fleet harness (static allocation, bench_scale-style)
# ---------------------------------------------------------------------------


def _drive_fleet(total: int, seed: int, *, tracer: Optional[Tracer],
                 ) -> Tuple[EventLoop, Dict[str, ClusterDriver], float]:
    """Deploy the static fleet, optionally install ``tracer``, drive to
    completion; wall covers ``loop.run`` only."""
    loop = EventLoop(kind="calendar")
    sink = StatsSink(eps=0.001)
    drivers: Dict[str, ClusterDriver] = {}
    for k, name in enumerate(sorted(MIX)):
        wf = get_workflow(name)
        allocs = {m: Allocation(replicas=REPLICAS[name], tp=1, fraction=1.0)
                  for m in wf.llms}
        routers = routers_from_allocations(wf, allocs, loop)
        for r in {id(r): r for r in routers.values()}.values():
            for e in r.replicas:
                e.keep_done = False
        drv = ClusterDriver(wf, routers, loop, sink=sink)
        n = max(1, round(total * MIX[name]))
        drv.schedule_open_loop(RATES[name], n, seed=seed,
                               arrival_seed=seed * 1000 + k)
        drivers[name] = drv
    install_tracer(tracer, drivers=drivers.values())
    t0 = time.perf_counter()
    loop.run(math.inf)
    return loop, drivers, time.perf_counter() - t0


def _completion_trace(drivers: Dict[str, ClusterDriver]):
    """Bit-exact per-driver completion fingerprint.  ``keep_done=False``
    fleets retain no records, so fingerprint counters + the StatsSink
    sketch quantiles instead (any behavioral divergence moves both)."""
    out = []
    for name in sorted(drivers):
        d = drivers[name]
        sink = d.sink
        out.append((name, d.n_started, d.n_completed,
                    sink.latency_quantile(name, 0.50),
                    sink.latency_quantile(name, 0.99),
                    sink.stats[name].lat_sum if name in sink.stats else 0.0))
    return out


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------


def zero_cost_section(s: dict, seed: int) -> dict:
    n = s["zero_cost_requests"]
    print(f"[obs] zero-cost: {n} requests x 3 arms ...", flush=True)
    _, drv_none, _ = _drive_fleet(n, seed, tracer=None)
    disabled = Tracer(enabled=False, seed=seed)
    _, drv_off, _ = _drive_fleet(n, seed, tracer=disabled)
    enabled = Tracer(sample_per_workflow=s["sample_per_workflow"],
                     seed=seed + 7)
    _, drv_on, _ = _drive_fleet(n, seed, tracer=enabled)
    base = _completion_trace(drv_none)
    off = _completion_trace(drv_off)
    on = _completion_trace(drv_on)
    return {
        "requests": n,
        "disabled_identical": off == base,
        "enabled_identical": on == base,
        "completions": {name: c for name, _, c, *_ in base},
        "sampled": enabled.sampled_counts(),
    }


def overhead_section(s: dict, seed: int) -> dict:
    n = s["overhead_requests"]
    trials = s["overhead_trials"]
    print(f"[obs] overhead: {n} requests, best-of-{trials}, "
          f"interleaved arms ...", flush=True)
    base_walls, traced_walls = [], []
    events = sampled = None
    for t in range(trials):
        loop_b, _, wall_b = _drive_fleet(n, seed, tracer=None)
        tracer = Tracer(sample_per_workflow=s["sample_per_workflow"],
                        seed=seed + 7)
        loop_t, _, wall_t = _drive_fleet(n, seed, tracer=tracer)
        base_walls.append(wall_b)
        traced_walls.append(wall_t)
        events = loop_t.events_processed
        sampled = tracer.sampled_counts()
        print(f"[obs]   trial {t}: base {wall_b:.2f}s "
              f"traced {wall_t:.2f}s", flush=True)
    # paired ratios: each trial runs both arms back to back, so slow
    # windows on a noisy machine hit both and cancel; the min over
    # trials then filters one-sided load spikes
    paired = [t / max(b, 1e-9)
              for b, t in zip(base_walls, traced_walls)]
    ratio = min(paired)
    return {
        "requests": n,
        "trials": trials,
        "base_wall_s": base_walls,
        "traced_wall_s": traced_walls,
        "paired_ratios": paired,
        "overhead_ratio": ratio,
        "events_processed": events,
        "sampled": sampled,
        "gate": OVERHEAD_GATE,
    }


def accuracy_section(s: dict, seed: int) -> Tuple[dict, Tracer]:
    n_req = s["accuracy_requests"]
    lams = dict(ACCURACY_FLEET)
    print(f"[obs] accuracy: pooled registry fleet, {n_req} requests "
          f"per workflow ...", flush=True)
    pipes, wfs = {}, {}
    for name in lams:
        wf = get_workflow(name)
        wfs[name] = wf
        pipes[name], _, _ = build_pipeline(
            wf, n_trace_requests=s["n_trace"], tp_degrees=(1, 2),
            max_profile_groups=s["profile_groups"], seed=seed)
    res = schedule_multi(pipes, hw.PAPER_CLUSTER_16, lams,
                         SchedulerConfig(max_tp=2), mode="pooled")
    pooled = res.pooled
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop)
    per_wf = pooled_fleet_routers(tenants, pooled.members, pooled.routing)
    monitor = DriftMonitor(
        {n: expectation_from(pipes[n], lams[n]) for n in wfs})
    drivers = {n: ClusterDriver(wfs[n], per_wf[n], loop, telemetry=monitor)
               for n in wfs}
    tracer = Tracer(sample_per_workflow=s["sample_per_workflow"],
                    seed=seed + 7)
    install_tracer(tracer, drivers=drivers.values())
    for k, name in enumerate(sorted(drivers)):
        drivers[name].schedule_open_loop(lams[name], n_req, seed=seed,
                                         arrival_seed=seed * 1000 + k)
    loop.run(math.inf)

    merged = merge_pipelines(pipes, lams)
    expected = {w: expected_shares(merged, w) for w in wfs}
    predictions = merged.attribute(pooled.allocations)
    report = accuracy_report(tracer, expected, predictions=predictions,
                             monitor=monitor)
    max_residual = max(
        (row["residual_rel"] for row in report["critical_path"].values()),
        default=0.0)
    corroborated = all(
        cell["agree"]
        for row in report["corroboration"].values()
        for cell in row.values())
    section = {
        "fleet": sorted(lams),
        "requests_per_workflow": n_req,
        "completed": {n: d.n_completed for n, d in drivers.items()},
        "expected_shares": expected,
        "observed_shares": tracer.observed_shares(),
        "share_max_rel_err": report["shares"]["max_rel_err"],
        "share_gate": SHARE_GATE,
        "critical_path": report["critical_path"],
        "breakdown_max_residual_rel": max_residual,
        "predictor": report["predictor"],
        "monitor_corroborates": corroborated,
    }
    return section, tracer


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def run(quick: bool = False, smoke: bool = False, seed: int = 0,
        out=None, dump=None) -> dict:
    t_run0 = time.perf_counter()
    s = _settings(quick, smoke)

    zero_cost = zero_cost_section(s, seed)
    overhead = overhead_section(s, seed)
    accuracy, tracer = accuracy_section(s, seed)

    acceptance = {
        "disabled_bit_identical": zero_cost["disabled_identical"],
        "enabled_bit_identical": zero_cost["enabled_identical"],
        "overhead_le_5pct": overhead["overhead_ratio"] <= OVERHEAD_GATE,
        "shares_within_15pct": accuracy["share_max_rel_err"] <= SHARE_GATE,
        "breakdown_sums_to_latency": (
            accuracy["breakdown_max_residual_rel"] <= RESIDUAL_GATE),
        "monitor_corroborates_tracer": accuracy["monitor_corroborates"],
        "branching_dag_traced": (
            zero_cost["sampled"].get("beam_search", {}).get("seen", 0) > 0),
    }

    doc = {
        "benchmark": "observability",
        "mode": s["mode"],
        "seed": seed,
        "config": {**s, "rates": RATES, "replicas": REPLICAS,
                   "accuracy_fleet": dict(ACCURACY_FLEET),
                   "gates": {"overhead": OVERHEAD_GATE,
                             "share_rel_err": SHARE_GATE,
                             "breakdown_residual": RESIDUAL_GATE}},
        "zero_cost": zero_cost,
        "overhead": overhead,
        "accuracy": accuracy,
        "acceptance": acceptance,
    }
    doc["meta"] = run_metadata(seed=seed,
                               config={"quick": quick, "smoke": smoke},
                               started=t_run0)
    text = json.dumps(doc, indent=2)
    print(text)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    if dump:
        with open(dump, "w") as f:
            json.dump(tracer.export(), f, indent=2)
        print(f"[obs] tracer export written to {dump}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true", help="full-size runs")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (schema-identical)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="also write the JSON report here")
    ap.add_argument("--dump", default=None,
                    help="write the accuracy run's tracer export "
                         "(spans + metrics) here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed,
        out=args.out, dump=args.dump)


if __name__ == "__main__":
    main()
