"""Fig. 11 — GPU-scheduler search time scaling: #LLMs, #GPUs, fractions
per GPU.  Synthetic analytic profiles so only the search is measured.

Also reports the fleet split search's warm-start delta: sharing each
workflow's best_option_for table across the sub-cluster sizes the
water-filling loop visits (the table depends only on (stage, units),
never on the chip count)."""
from __future__ import annotations

import time

from benchmarks.common import cluster_for
from repro import hw
from repro.configs.base import ArchConfig
from repro.core.pipeline import AggregateLLMPipeline, PipelineStage
from repro.core.profiler import LLMProfile, TPProfile
from repro.core.scheduler import SchedulerConfig, schedule, schedule_multi


def _synthetic_stage(name: str, size_gb: float, n: float = 4.0,
                     p: float = 2.0) -> PipelineStage:
    """Analytic M/M/1-flavored profile for a model of given size."""
    base_lat = 0.05 * size_gb  # unloaded latency
    t_max = 40.0 / size_gb  # capacity
    by_tp = {}
    for tp in (1, 2, 4):
        tmax = t_max * (tp ** 0.85)
        rates = [f * tmax for f in (0.1, 0.3, 0.5, 0.7, 0.9)]
        lat = [base_lat / tp / max(1 - r / tmax, 0.05) for r in rates]
        by_tp[tp] = TPProfile(tp=tp, rates=rates,
                              latency={"mean": lat, "p50": lat,
                                       "p90": [2 * x for x in lat],
                                       "p99": [4 * x for x in lat]},
                              max_throughput=tmax)
    cfg = ArchConfig(name=name, family="dense", num_layers=16,
                     d_model=2048, num_heads=16, num_kv_heads=8,
                     d_ff=8192, vocab_size=32_000)
    prof = LLMProfile(llm=name, arch=name, calls_per_group=n, by_tp=by_tp)
    return PipelineStage(llm=name, cfg=cfg, n=n, p=p, profile=prof,
                         mean_share=1.0)


def _pipeline(n_llms: int) -> AggregateLLMPipeline:
    stages = [_synthetic_stage(f"llm{i}", size_gb=1.0 + 3.0 * i, n=2.0 + i)
              for i in range(n_llms)]
    return AggregateLLMPipeline("synthetic", stages)


def run(quick: bool = False):
    print("sweep,value,search_time_s,evaluated,feasible")
    results = []

    def one(tag, value, pipeline, spec):
        t0 = time.perf_counter()
        try:
            res = schedule(pipeline, spec, lam_target=0.5,
                           config=SchedulerConfig(max_tp=spec.hb_domain_size))
            dt = time.perf_counter() - t0
            print(f"{tag},{value},{dt:.4f},{res.evaluated},{res.feasible}")
            results.append((tag, value, dt, res.evaluated))
        except (ValueError, RuntimeError) as e:
            print(f"{tag},{value},nan,0,error:{type(e).__name__}")

    # 1) number of LLMs (16 GPUs, 10 fractions)
    for n in range(2, 6 if quick else 7):
        one("num_llms", n, _pipeline(n), hw.PAPER_CLUSTER_16)
    # 2) number of GPUs (3 LLMs, 10 fractions)
    for chips in (16, 32, 64) if quick else (16, 32, 64, 128):
        one("num_gpus", chips, _pipeline(3), cluster_for(chips))
    # 3) fractions per GPU (3 LLMs, 16 GPUs)
    for frac in (5, 10, 20):
        spec = hw.ClusterSpec(num_hosts=4, chips_per_host=4,
                              fractions_per_chip=frac)
        one("fractions_per_gpu", frac, _pipeline(3), spec)

    # 4) option-table memoization: same assignment count, lower search
    # time (best_option_for depends only on (llm, units), so its results
    # are shared across enumerated splits)
    print("memoize,num_llms,chips,search_time_s,evaluated")
    for n_llms, spec in ((3, hw.PAPER_CLUSTER_16),
                         (4, hw.PAPER_CLUSTER_16)):
        evaluated = {}
        for memo in (False, True):
            cfg = SchedulerConfig(max_tp=spec.hb_domain_size, memoize=memo)
            t0 = time.perf_counter()
            res = schedule(_pipeline(n_llms), spec, lam_target=0.5,
                           config=cfg)
            dt = time.perf_counter() - t0
            evaluated[memo] = res.evaluated
            print(f"{memo},{n_llms},{spec.num_chips},{dt:.4f},"
                  f"{res.evaluated}")
            results.append((f"memoize_{memo}", n_llms, dt, res.evaluated))
        assert evaluated[True] == evaluated[False], \
            "memoization must not change the searched assignment count"

    # 5) fleet-search warm start: option tables shared across the split
    # search's sub-cluster sizes (ROADMAP "warm-start each sub-schedule
    # from the neighbouring chip count's result") — same splits, same
    # welfare, lower search time
    print("warm_start,num_workflows,chips,search,search_time_s,"
          "schedule_calls,welfare")
    fleets = [(4, 64, "greedy"), (3, 64, "enumerate")]
    if not quick:
        fleets.append((8, 128, "greedy"))
    for n_wf, chips, search in fleets:
        spec = cluster_for(chips)
        pipes = {f"wf{i}": _pipeline(2 + i % 3) for i in range(n_wf)}
        lams = {f"wf{i}": 2.0 + 0.3 * i for i in range(n_wf)}
        welfare = {}
        for warm in (False, True):
            cfg = SchedulerConfig(max_tp=spec.hb_domain_size,
                                  warm_start=warm)
            t0 = time.perf_counter()
            res = schedule_multi(pipes, spec, lams, cfg, search=search)
            dt = time.perf_counter() - t0
            welfare[warm] = res.welfare
            print(f"{warm},{n_wf},{chips},{search},{dt:.4f},"
                  f"{res.schedule_calls},{res.welfare:.6f}")
            results.append((f"warm_start_{warm}", n_wf, dt,
                            res.schedule_calls))
        assert welfare[True] == welfare[False], \
            "warm start must not change the chosen split's welfare"
    return results


if __name__ == "__main__":
    run(quick=True)
