"""Fig. 8 — Scepsy vs Ayo-like workflow-aware serving (static allocation).

Expected shape: Ayo is latency-competitive at low rates (request-level
optimizations) but hits its throughput ceiling early because the static,
demand-blind allocation starves the bottleneck LLM."""
from __future__ import annotations

from repro.core.scepsy import build_pipeline
from benchmarks.common import HEADER, cluster_for, run_ayo, run_scepsy
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER

RATES = {"beam_search": (0.08, 0.2, 0.35, 0.5),
         "rag_reranker": (1.0, 3.0, 5.0, 8.0)}


def run(quick: bool = False):
    n_req = 30 if quick else 80
    print(HEADER)
    results = []
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        pipeline, _, _ = build_pipeline(
            wf, n_trace_requests=15 if quick else 40, tp_degrees=(1, 2),
            max_profile_groups=12)
        for chips in (4, 8):
            spec = cluster_for(chips)
            for base in RATES[wf.name]:
                rate = base * chips / 4
                r1 = run_scepsy(wf, pipeline, spec, rate, n_req)
                r2 = run_ayo(wf, spec, rate, n_req)
                print(r1.row())
                print(r2.row())
                results.extend([r1, r2])
    return results


if __name__ == "__main__":
    run(quick=True)
