"""Shared benchmark harness: run a serving system at an arrival rate and
measure the workflow-level throughput-latency point."""
from __future__ import annotations

import math
import os
import platform
import statistics
import subprocess
import time
from dataclasses import dataclass
from typing import Dict, Optional

from repro import hw
from repro.core.pipeline import AggregateLLMPipeline
from repro.core.scheduler import (PooledScheduleResult, SchedulerConfig,
                                  schedule)
from repro.serving.deploy import (pooled_fleet_routers,
                                  routers_from_allocations, tenant_routers)
from repro.serving.simulator import EventLoop, Router
from repro.workflows.baselines import AegaeonLike, AyoLike, KubernetesHPA
from repro.workflows.runtime import ClusterDriver, Workflow


@dataclass
class RunResult:
    system: str
    workflow: str
    chips: int
    offered_rate: float
    achieved_throughput: float
    mean_latency: float
    p50_latency: float
    p99_latency: float
    completed: int

    def row(self) -> str:
        return (f"{self.system},{self.workflow},{self.chips},"
                f"{self.offered_rate:.3f},{self.achieved_throughput:.3f},"
                f"{self.mean_latency:.3f},{self.p50_latency:.3f},"
                f"{self.p99_latency:.3f},{self.completed}")


HEADER = ("system,workflow,chips,offered_rate,achieved_tput,"
          "mean_latency_s,p50_latency_s,p99_latency_s,completed")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or os.environ.get("GITHUB_SHA", "unknown")[:12]
    except (OSError, subprocess.SubprocessError):
        return os.environ.get("GITHUB_SHA", "unknown")[:12] or "unknown"


def run_metadata(*, seed: int, config: Optional[dict] = None,
                 started: Optional[float] = None) -> dict:
    """Provenance stamp every bench JSON carries under ``"meta"``:
    seed, git SHA, python version, the bench's config knobs, and (when
    ``started`` — a ``time.perf_counter()`` reading taken at bench
    start — is given) the wall-clock duration.  ``benchmarks.validate``
    requires the stamp on every report."""
    meta = {
        "seed": seed,
        "git_sha": _git_sha(),
        "python": platform.python_version(),
        "config": dict(config or {}),
    }
    if started is not None:
        meta["wall_s"] = time.perf_counter() - started
    return meta


def measure(wf: Workflow, routers: Dict[str, Router], rate: float,
            n_requests: int, *, system: str, chips: int,
            seed: int = 0, horizon_factor: float = 6.0) -> RunResult:
    loop = next(iter(routers.values())).replicas[0].loop \
        if hasattr(next(iter(routers.values())), "replicas") else None
    # all engines share one loop; fish it out via duck-typing
    if loop is None:
        loop = routers[next(iter(routers))].system.loop  # aegaeon
    driver = ClusterDriver(wf, routers, loop)
    horizon = max(n_requests / max(rate, 1e-9) * horizon_factor, 600.0)
    recs = driver.run_open_loop(rate, n_requests, seed=seed, until=horizon)
    if not recs:
        return RunResult(system, wf.name, chips, rate, 0.0, math.inf,
                         math.inf, math.inf, 0)
    lats = [r.latency for r in recs]
    span = max(r.done for r in recs) - min(r.arrival for r in recs)
    return RunResult(
        system=system, workflow=wf.name, chips=chips, offered_rate=rate,
        achieved_throughput=len(recs) / max(span, 1e-9),
        mean_latency=statistics.mean(lats),
        p50_latency=statistics.median(lats),
        p99_latency=sorted(lats)[min(int(0.99 * len(lats)), len(lats) - 1)],
        completed=len(recs))


def joint_run(wf_allocs, rates: Dict[str, float], n_req: int, *,
              seed: int = 0, horizon: float = 1e5) -> Dict[str, dict]:
    """Drive several workflows' ClusterDrivers on one shared EventLoop
    (interleaved Poisson arrivals); per-workflow completion + mean
    latency.  ``wf_allocs`` is a list of (Workflow, allocations)."""
    loop = EventLoop()
    drivers: Dict[str, ClusterDriver] = {}
    for wf, allocs in wf_allocs:
        routers = routers_from_allocations(wf, allocs, loop)
        drivers[wf.name] = ClusterDriver(wf, routers, loop)
    return drive_fleet(drivers, rates, n_req, loop,
                       seed=seed, horizon=horizon)


def drive_fleet(drivers: Dict[str, ClusterDriver],
                rates: Dict[str, float], n_req: int, loop: EventLoop, *,
                seed: int = 0, horizon: float = 1e5) -> Dict[str, dict]:
    # lazy sources: one pending arrival per driver, same RNG streams as
    # the old eager pre-scheduling (arrival process from seed*1000+k,
    # request programs from seed)
    for k, name in enumerate(sorted(drivers)):
        drivers[name].schedule_open_loop(rates[name], n_req, seed=seed,
                                         arrival_seed=seed * 1000 + k)
    loop.run(horizon)
    out: Dict[str, dict] = {}
    for name, drv in drivers.items():
        recs = [r for r in drv.records if r.done >= 0]
        out[name] = {
            "completed": len(recs),
            "mean_latency_s": (statistics.mean(r.latency for r in recs)
                               if recs else math.inf),
        }
    return out


def joint_run_pooled(wfs: Dict[str, Workflow], pooled: PooledScheduleResult,
                     rates: Dict[str, float], n_req: int, *,
                     seed: int = 0, horizon: float = 1e5) -> Dict[str, dict]:
    """Drive a pooled fleet: ONE shared replica set per tenant, each
    workflow routing into it via its weighted view.  Same output shape
    as :func:`joint_run`."""
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop)
    per_wf = pooled_fleet_routers(tenants, pooled.members, pooled.routing)
    drivers = {name: ClusterDriver(wfs[name], per_wf[name], loop)
               for name in wfs}
    return drive_fleet(drivers, rates, n_req, loop,
                       seed=seed, horizon=horizon)


def cluster_for(chips: int) -> hw.ClusterSpec:
    if chips <= 4:
        return hw.PAPER_CLUSTER_4
    if chips <= 8:
        return hw.PAPER_CLUSTER_8
    return hw.ClusterSpec(num_hosts=chips // 4, chips_per_host=4)


def run_scepsy(wf: Workflow, pipeline: AggregateLLMPipeline,
               spec: hw.ClusterSpec, rate: float, n_requests: int,
               seed: int = 0, scheduler_config: Optional[SchedulerConfig] = None
               ) -> RunResult:
    cfgsch = scheduler_config or SchedulerConfig(max_tp=spec.hb_domain_size)
    res = schedule(pipeline, spec, rate, cfgsch)
    loop = EventLoop()
    routers = routers_from_allocations(wf, res.allocations, loop)
    return measure(wf, routers, rate, n_requests, system="scepsy",
                   chips=spec.num_chips, seed=seed)


def run_k8s(wf: Workflow, spec: hw.ClusterSpec, rate: float,
            n_requests: int, seed: int = 0) -> RunResult:
    loop = EventLoop()
    sysm = KubernetesHPA(wf, spec, loop)
    return measure(wf, sysm.routers, rate, n_requests, system="k8s-hpa",
                   chips=spec.num_chips, seed=seed)


def run_aegaeon(wf: Workflow, spec: hw.ClusterSpec, rate: float,
                n_requests: int, seed: int = 0, split=(2, 2)) -> RunResult:
    loop = EventLoop()
    sysm = AegaeonLike(wf, spec, loop, prefill_per_node=split[0],
                       decode_per_node=split[1])
    driver = ClusterDriver(wf, sysm.routers, loop)
    horizon = max(n_requests / max(rate, 1e-9) * 6.0, 600.0)
    recs = driver.run_open_loop(rate, n_requests, seed=seed, until=horizon)
    import statistics as st

    if not recs:
        return RunResult(f"aegaeon-{split[0]}P{split[1]}D", wf.name,
                         spec.num_chips, rate, 0.0, math.inf, math.inf,
                         math.inf, 0)
    lats = [r.latency for r in recs]
    span = max(r.done for r in recs) - min(r.arrival for r in recs)
    return RunResult(f"aegaeon-{split[0]}P{split[1]}D", wf.name,
                     spec.num_chips, rate, len(recs) / max(span, 1e-9),
                     st.mean(lats), st.median(lats),
                     sorted(lats)[min(int(0.99 * len(lats)), len(lats) - 1)],
                     len(recs))


def run_ayo(wf: Workflow, spec: hw.ClusterSpec, rate: float,
            n_requests: int, seed: int = 0) -> RunResult:
    loop = EventLoop()
    sysm = AyoLike(wf, spec, loop)
    return measure(wf, sysm.routers, rate, n_requests, system="ayo",
                   chips=spec.num_chips, seed=seed)
