"""Fig. 3 — absolute vs relative per-LLM execution-time distributions.

Reproduces the paper's motivating observation: per-request absolute LLM
times vary wildly (CoV ~0.7+) while relative shares are far more stable
(the paper reports up to 4x; we typically see 10x+)."""
from __future__ import annotations

from repro.core.aggregate import aggregate
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER
from repro.workflows.runtime import trace_workflow


def run(quick: bool = False):
    rows = []
    n = 40 if quick else 200
    print("workflow,llm,n_per_req,parallelism,share,abs_cov,share_cov,"
          "stability_gain")
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        stats = aggregate(trace_workflow(wf, n, seed=7))
        for m, st in stats.per_llm.items():
            gain = st.abs_cov / max(st.share_cov, 1e-9)
            row = (f"{wf.name},{m},{st.n:.1f},{st.p:.2f},{st.mean_share:.3f},"
                   f"{st.abs_cov:.3f},{st.share_cov:.3f},{gain:.1f}")
            print(row)
            rows.append((wf.name, m, st.abs_cov, st.share_cov, gain))
    return rows


if __name__ == "__main__":
    run()
