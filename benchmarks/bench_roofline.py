"""§Roofline — per (arch × shape × mesh) roofline terms from the dry-run
artifacts in experiments/dryrun/ (single-pod table per the assignment).

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = collective_bytes_per_device / (links x link_bw)

FLOPs/bytes come from the while-trip-count-aware HLO analyzer (XLA's own
cost_analysis counts scan bodies once — see repro.analysis.hlo_stats).
MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (inference) per device.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro import hw
from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import get_config

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    """Useful model FLOPs: 6·N_active·D (train) / 2·N_active·D (inference)
    plus the attention-score FLOPs at the shape's context (which dominate
    long-context cells and would otherwise make the ratio unfairly low for
    attention-heavy archs)."""
    from repro.serving.costmodel import flops_per_token

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # fwd+bwd = 3x fwd; avg causal context = S/2
        return 3.0 * tokens * flops_per_token(cfg, shape.seq_len // 2) / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return tokens * flops_per_token(cfg, shape.seq_len // 2) / devices
    tokens = shape.global_batch  # decode: one token per sequence
    return tokens * flops_per_token(cfg, shape.seq_len) / devices


def roofline_row(rec: dict) -> dict:
    hlo = rec["hlo_stats"]
    devices = rec["num_devices"]
    compute_s = hlo["flops"] / hw.PEAK_FLOPS_BF16
    memory_s = hlo["hbm_bytes"] / hw.HBM_BW
    link_bw = hw.ICI_LINKS_PER_CHIP * hw.ICI_LINK_BW
    collective_s = hlo["total_collective_bytes"] / link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], devices)
    step_s = max(compute_s, memory_s) + collective_s
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops": hlo["flops"],
        "useful_ratio": mf / hlo["flops"] if hlo["flops"] else 0.0,
        "mfu_bound": mf / hw.PEAK_FLOPS_BF16 / step_s if step_s else 0.0,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "arg_gib": rec["memory"]["argument_bytes"] / 2**30,
    }


def load_rows(mesh: str = "pod16x16"):
    rows = []
    for f in sorted(RESULTS_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok" or "hlo_stats" not in rec:
            continue
        rows.append(roofline_row(rec))
    return rows


def run(quick: bool = False):
    rows = load_rows()
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_flops_ratio,roofline_fraction,temp_GiB")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['mfu_bound']:.3f},"
              f"{r['temp_gib']:.2f}")
    return rows


if __name__ == "__main__":
    run()
