"""Scale benchmark: the million-request event core.

Drives a registry fleet (rag_reranker + react_agent + map_reduce +
debate — the zoo workload ``rag_reranker`` rides along per the ISSUE
satellite) to >= 10^6 workflow requests on one shared event loop and
gates the rebuilt core:

* ``throughput`` — driver-loop events/sec on the new path (calendar
  queue + lazy arrival sources + indexed routers + heap-served cache
  eviction + aggregate ``StatsSink`` telemetry, ``keep_done=False``
  engines) vs the legacy path (binary heap + eager pre-scheduled
  arrivals + full-scan routers with O(queue) per-call load
  recomputation + DFS-walk cache eviction + exact per-request
  records), both measured in-bench on the same fleet.
  Acceptance: >= 4x.
* ``memory`` — peak tracked objects are O(in-flight), not O(total):
  ``loop.peak_pending`` (lazy sources keep one pending arrival per
  driver), the sink's ``peak_inflight``, and zero retained per-request
  records on the new path.  ``ru_maxrss`` is reported informationally.
* ``sketch`` — on a smoke-sized side run the GK sketch's p50/p99 stay
  within 2% (value-relative) of exact-record quantiles.
* ``parity`` — calendar vs heap completion traces are identical on a
  seeded mini-fleet (the same invariant tier-1 tests enforce, asserted
  in-bench so the report is self-contained).

JSON schema (``benchmark: "scale_event_core"``) is documented in
benchmarks/README.md; ``--smoke`` is the tiny CI mode (schema-identical,
~10^4 requests).  A full run also refreshes ``BENCH_scale.json`` at the
repo root so the perf trajectory is recorded in-tree.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import resource
import time
from typing import Dict, Optional, Tuple

from benchmarks.common import run_metadata
from repro.core.scheduler import Allocation
from repro.core.telemetry import StatsSink
from repro.serving.deploy import routers_from_allocations
from repro.serving.simulator import EventLoop, Router
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver

# per-workflow Poisson rates (req/s) and replicas per LLM role, sized
# from measured sustained capacity so every class runs loaded-but-
# stable (~60% of its saturation throughput; in-flight stays bounded).
# The request mix follows the rates: the interactive agent dominates,
# the heavyweight pipelines trickle.  Every driver spans the same sim
# horizon because n_wf is proportional to rate_wf.
RATES: Dict[str, float] = {
    "react_agent": 16.0,
    "debate": 1.1,
    "rag_reranker": 0.9,
    "map_reduce": 0.5,
}
REPLICAS: Dict[str, int] = {
    "react_agent": 6,
    "debate": 4,
    "rag_reranker": 8,
    "map_reduce": 8,
}
TOTAL_RATE = sum(RATES.values())
MIX: Dict[str, float] = {k: v / TOTAL_RATE for k, v in RATES.items()}


def _settings(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {"mode": "smoke", "total_requests": 10_000,
                "legacy_cap": 4_000, "sketch_requests": 3_000}
    if quick:
        return {"mode": "quick", "total_requests": 100_000,
                "legacy_cap": 15_000, "sketch_requests": 5_000}
    return {"mode": "full", "total_requests": 1_000_000,
            "legacy_cap": 40_000, "sketch_requests": 8_000}


def _build_and_drive(total: int, seed: int, *,
                     kind: str, indexed: bool, eager: bool,
                     sink: Optional[StatsSink], keep_done: bool,
                     legacy: bool = False,
                     ) -> Tuple[EventLoop, Dict[str, ClusterDriver], float]:
    """Deploy the fleet, drive every workflow to completion, and return
    (loop, drivers, wall_seconds) where wall covers ``loop.run`` only."""
    loop = EventLoop(kind=kind)
    drivers: Dict[str, ClusterDriver] = {}
    for k, name in enumerate(sorted(MIX)):
        wf = get_workflow(name)
        allocs = {m: Allocation(replicas=REPLICAS[name], tp=1, fraction=1.0)
                  for m in wf.llms}
        routers = routers_from_allocations(wf, allocs, loop)
        if not indexed:
            routers = {m: Router(r.replicas, affinity=r.affinity,
                                 indexed=False, legacy_load=legacy)
                       for m, r in routers.items()}
        for r in {id(r): r for r in routers.values()}.values():
            for e in r.replicas:
                if not keep_done:
                    e.keep_done = False
                if legacy:
                    e.radix.legacy_evict = True
        drv = ClusterDriver(wf, routers, loop, sink=sink)
        n = max(1, round(total * MIX[name]))
        drv.schedule_open_loop(RATES[name], n, seed=seed,
                               arrival_seed=seed * 1000 + k, eager=eager)
        drivers[name] = drv
    t0 = time.perf_counter()
    loop.run(math.inf)
    return loop, drivers, time.perf_counter() - t0


def _quantiles(lats) -> Dict[str, float]:
    lats = sorted(lats)
    pick = lambda q: lats[min(int(q * len(lats)), len(lats) - 1)]
    return {"p50": pick(0.50), "p99": pick(0.99)}


def _mini_trace(kind: str, seed: int):
    _, drivers, _ = _build_and_drive(600, seed, kind=kind,
                                     indexed=True, eager=False,
                                     sink=None, keep_done=True)
    return [[(r.request_id, r.arrival, r.done) for r in d.records]
            for _, d in sorted(drivers.items())]


def run(quick: bool = False, smoke: bool = False, seed: int = 0,
        out: Optional[str] = None) -> dict:
    t_run0 = time.perf_counter()
    s = _settings(quick, smoke)
    total = s["total_requests"]

    # --- new path: calendar + lazy + indexed + sink, no retained records
    print(f"[scale] new path: {total} requests at {TOTAL_RATE:.1f}/s "
          f"aggregate ...", flush=True)
    sink = StatsSink(eps=0.001)
    loop_new, drv_new, wall_new = _build_and_drive(
        total, seed, kind="calendar", indexed=True, eager=False,
        sink=sink, keep_done=False)
    completed_new = sum(d.n_completed for d in drv_new.values())
    started_new = sum(d.n_started for d in drv_new.values())
    eps_new = loop_new.events_processed / max(wall_new, 1e-9)
    print(f"[scale]   {loop_new.events_processed} events in "
          f"{wall_new:.1f}s -> {eps_new:,.0f} ev/s; "
          f"completed {completed_new}/{started_new}", flush=True)

    # --- legacy path: heap + eager + full-scan routers with O(queue)
    # load recomputation + DFS-walk cache eviction + exact records;
    # events/sec is intensive, so the baseline runs a capped request
    # count (eager pre-scheduling at 10^6 would swamp memory — which is
    # the point of the tentpole)
    n_legacy = min(total, s["legacy_cap"])
    print(f"[scale] legacy path: {n_legacy} requests ...", flush=True)
    loop_old, drv_old, wall_old = _build_and_drive(
        n_legacy, seed, kind="heap", indexed=False, eager=True,
        sink=None, keep_done=True, legacy=True)
    eps_old = loop_old.events_processed / max(wall_old, 1e-9)
    print(f"[scale]   {loop_old.events_processed} events in "
          f"{wall_old:.1f}s -> {eps_old:,.0f} ev/s", flush=True)
    speedup = eps_new / max(eps_old, 1e-9)

    # --- memory: tracked-object peaks must scale with in-flight work
    inflight_bound = max(2_000, total // 20)
    records_new = sum(len(d.records) for d in drv_new.values())
    memory = {
        "total_requests": total,
        "loop_peak_pending_new": loop_new.peak_pending,
        "loop_peak_pending_legacy": loop_old.peak_pending,
        "sink_peak_inflight": sink.peak_inflight,
        "retained_records_new": records_new,
        "retained_records_legacy": sum(len(d.records)
                                       for d in drv_old.values()),
        "inflight_bound": inflight_bound,
        "ru_maxrss_kb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss,
    }

    # --- sketch accuracy: exact records vs StatsSink on one seeded run
    ns = s["sketch_requests"]
    print(f"[scale] sketch check: {ns} requests, exact vs sink ...",
          flush=True)
    _, drv_exact, _ = _build_and_drive(ns, seed + 1, kind="calendar",
                                       indexed=True, eager=False,
                                       sink=None, keep_done=True)
    sink2 = StatsSink(eps=0.001)
    _build_and_drive(ns, seed + 1, kind="calendar", indexed=True,
                     eager=False, sink=sink2, keep_done=False)
    # the 2% value gate needs enough samples that one rank step at p99
    # moves the value far less than 2% — low-volume workflows are
    # reported but not gated (their p99 neighborhood is too sparse for
    # *any* estimator, exact or sketched)
    gate_min = 1_000
    sketch: Dict[str, dict] = {"eps": sink2.eps, "gate_min_completed":
                               gate_min, "workflows": {}}
    worst_rel = 0.0
    for name, d in drv_exact.items():
        lats = [r.latency for r in d.records if r.done >= 0]
        exact_q = _quantiles(lats)
        row = {"completed": len(lats), "gated": len(lats) >= gate_min}
        for label, q in (("p50", 0.50), ("p99", 0.99)):
            approx = sink2.latency_quantile(name, q)
            rel = abs(approx - exact_q[label]) / max(exact_q[label], 1e-12)
            if row["gated"]:
                worst_rel = max(worst_rel, rel)
            row[label] = {"exact": exact_q[label], "sketch": approx,
                          "rel_err": rel}
        sketch["workflows"][name] = row
    sketch["worst_rel_err_gated"] = worst_rel

    # --- in-bench parity spot-check: calendar vs heap traces identical
    parity_ok = _mini_trace("calendar", seed) == _mini_trace("heap", seed)

    acceptance = {
        "all_requests_completed": completed_new == started_new == total,
        "speedup_4x": speedup >= 4.0,
        "memory_bounded": (loop_new.peak_pending < inflight_bound
                           and sink.peak_inflight < inflight_bound
                           and records_new == 0),
        "sketch_within_2pct": worst_rel <= 0.02,
        "calendar_heap_parity": parity_ok,
    }

    doc = {
        "benchmark": "scale_event_core",
        "seed": seed,
        "config": {**s, "rates": RATES, "total_rate": TOTAL_RATE,
                   "mix": MIX, "replicas": REPLICAS, "sink_eps": sink.eps},
        "throughput": {
            "new": {"events": loop_new.events_processed,
                    "wall_s": wall_new, "events_per_sec": eps_new,
                    "requests": total,
                    "requests_per_sec": total / max(wall_new, 1e-9)},
            "legacy": {"events": loop_old.events_processed,
                       "wall_s": wall_old, "events_per_sec": eps_old,
                       "requests": n_legacy},
            "speedup": speedup,
        },
        "memory": memory,
        "sketch": sketch,
        "workflows": {name: {"started": d.n_started,
                             "completed": d.n_completed}
                      for name, d in drv_new.items()},
        "acceptance": acceptance,
    }
    doc["meta"] = run_metadata(seed=seed,
                               config={"quick": quick, "smoke": smoke},
                               started=t_run0)
    text = json.dumps(doc, indent=2)
    targets = [out] if out else []
    if s["mode"] == "full":
        # record the perf trajectory in-tree
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        bench_json = os.path.join(root, "BENCH_scale.json")
        if bench_json not in (os.path.abspath(t) for t in targets):
            targets.append(bench_json)
    for path in targets:
        with open(path, "w") as f:
            f.write(text + "\n")
        print(f"[scale] wrote {path}")
    print(text)
    if not all(acceptance.values()):
        raise AssertionError(f"scale acceptance failed: {acceptance}")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="full-size run (>= 10^6 requests)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI mode (schema-identical)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write JSON report here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed,
        out=args.out)


if __name__ == "__main__":
    main()
