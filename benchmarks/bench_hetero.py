"""Heterogeneous chip classes + just-in-time model substitution.

Part A — **class-aware vs class-blind** on a mixed cluster (v5p +
v5e + v4i host groups):

* class-aware: each LLM is profiled per ``(chip_class, tp)``, the
  scheduler assigns every allocation a chip class from per-class unit
  budgets, and placement binds instances to compatible host groups —
  the big-HBM chips end up holding the models that only fit there;
* class-blind: the same chips flattened to ONE averaged class
  (:func:`repro.hw.blend_classes`) — the scheduler plans against the
  blend, allocations carry no bindings, and the packer drops replicas
  wherever they land.  Replicas run at the class of the chip they
  landed on, so a big model packed onto a small-HBM chip pays the real
  penalty (KV capacity collapses to ~nothing).

Both plans are driven on the SAME physical mixed cluster with the same
arrival streams; ``fleet_welfare`` is the egalitarian min over
workflows of goodput/target.

Part B — **JIT substitution under an overload burst** (bench_qos-style
pooled fleet): the batch-class workflows' rates multiply for a window;
``shed`` runs plain admission control (reject/degrade), ``substitute``
additionally re-prices over-deadline arrivals against the substitute
tier's replicas (``ArchConfig.substitute``) and reroutes them there at
their own SLO class.  The report carries per-workflow and per-SLO-class
substitution rates, and feeds the observed rates back into
:meth:`MergedPipeline.with_substitution` to show the share shift.

``acceptance``: class-aware strictly beats class-blind on fleet
welfare, and substitution recovers goodput vs plain shedding.  JSON
schema is documented in benchmarks/README.md; ``--smoke`` is the tiny
CI mode (schema-identical).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import time
from typing import Dict, List, Optional

from benchmarks.common import cluster_for, run_metadata
from repro import hw
from repro.core.pipeline import merge_pipelines
from repro.core.placement import PlacementError, place_fleet
from repro.core.scepsy import (_resolve_qos, _spec_chip_classes,
                               build_pipeline, deploy_multi)
from repro.core.scheduler import SchedulerConfig, schedule_multi
from repro.qos.admission import fleet_admission
from repro.qos.slo import WorkflowQoS
from repro.serving.deploy import (fleet_routers_from_placement,
                                  pooled_fleet_routers, tenant_routers)
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver


def _settings(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {
            "mode": "smoke",
            # Part A: mixed cluster
            "groups": (("v5p", 1, 4), ("v5e", 2, 4), ("v4i", 2, 4)),
            "hetero_lams": {"rag_reranker": 0.8, "react_agent": 5.5},
            "t_run": 120.0,
            "drain": 600.0,
            "n_trace": 8,
            "profile_groups": 6,
            # Part B: substitution burst (uniform pooled fleet)
            "sub_chips": 8,
            "sub_lams": {"react_agent": 1.0, "map_reduce": 0.8,
                         "debate": 1.6},
            "burst": {"map_reduce": 10.0, "debate": 12.0},
            "t_warm": 30.0,
            "t_burst": 90.0,
            "t_tail": 30.0,
            "sub_drain": 600.0,
        }
    return {
        "mode": "quick" if quick else "full",
        "groups": (("v5p", 2, 4), ("v5e", 4, 4), ("v4i", 2, 4)),
        "hetero_lams": {"rag_reranker": 1.3, "react_agent": 8.8},
        "t_run": 200.0 if quick else 400.0,
        "drain": 1200.0,
        "n_trace": 12 if quick else 30,
        "profile_groups": 10 if quick else 30,
        "sub_chips": 16,
        "sub_lams": {"react_agent": 1.5, "map_reduce": 1.2, "debate": 2.4},
        "burst": {"map_reduce": 10.0, "debate": 12.0},
        "t_warm": 40.0,
        "t_burst": 150.0 if quick else 300.0,
        "t_tail": 40.0,
        "sub_drain": 1200.0,
    }


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(int(q * len(xs)), len(xs) - 1)]


def _workflow_metrics(drv: ClusterDriver, slo, horizon: float) -> dict:
    recs = drv.records
    done = [r for r in recs if r.done >= 0]
    lats = [r.latency for r in done]
    met = sum(1 for r in done if r.slo_met)
    return {
        "slo_class": slo.name if slo else "",
        "arrived": len(recs),
        "completed": len(done),
        "rejected": sum(1 for r in recs if r.rejected),
        "degraded": sum(1 for r in recs if r.degraded),
        "substituted": sum(1 for r in recs if r.substituted),
        "slo_met": met,
        "goodput_rps": met / horizon,
        "mean_latency_s": statistics.mean(lats) if lats else 0.0,
        "p50_latency_s": _percentile(lats, 0.50),
        "p99_latency_s": _percentile(lats, 0.99),
    }


# ---------------------------------------------------------------------------
# Part A: class-aware vs class-blind on a mixed cluster
# ---------------------------------------------------------------------------


def _hetero_spec(s) -> hw.ClusterSpec:
    return hw.hetero_cluster([
        hw.HostGroup(num_hosts=n, chips_per_host=c, chip_class=cls)
        for cls, n, c in s["groups"]
    ])


def _blend_spec(s) -> hw.ClusterSpec:
    """The same chips flattened to one averaged class."""
    parts = [(hw.chip_class(cls), n * c) for cls, n, c in s["groups"]]
    blend = hw.blend_classes(parts, name="hetero-blend")
    hw.register_chip_class(blend)
    cph = max(c for _, _, c in s["groups"])
    hosts = sum(n for _, n, _ in s["groups"])
    return hw.hetero_cluster(
        [hw.HostGroup(num_hosts=hosts, chips_per_host=cph,
                      chip_class=blend.name)])


def _plan_fleet(wfs, lams, plan_spec, s, seed):
    """Profile per plan_spec's chip classes + partitioned schedule."""
    # placement-aware split search: on a mixed cluster the per-workflow
    # sub-cluster slices all start at group 0, so class-bound plans can
    # jointly overcommit a scarce class — the placement probe rejects
    # those splits and steers the search to ones that really deploy
    cfg = SchedulerConfig(max_tp=plan_spec.hb_domain_size,
                          placement_aware=True)
    pipelines, stats = {}, {}
    for name, wf in wfs.items():
        pipe, st, _ = build_pipeline(
            wf, n_trace_requests=s["n_trace"],
            max_profile_groups=s["profile_groups"], seed=seed,
            chip_classes=_spec_chip_classes(plan_spec))
        pipelines[name] = pipe
        stats[name] = st
    multi = schedule_multi(pipelines, plan_spec, lams, cfg,
                           mode="partitioned")
    qos = {}
    for name, wf in wfs.items():
        q = _resolve_qos(wf, pipelines[name], stats[name])
        if q is not None:
            qos[name] = q
    return pipelines, multi, qos


def _drive_hetero(wfs, placement, qos_by, lams, s, seed) -> dict:
    loop = EventLoop()
    per_wf = fleet_routers_from_placement(wfs, placement, loop)
    run_qos = {n: WorkflowQoS(slo=q.slo, work=q.work)
               for n, q in qos_by.items()}
    drivers: Dict[str, ClusterDriver] = {}
    for k, name in enumerate(sorted(wfs)):
        drv = ClusterDriver(wfs[name], per_wf[name], loop,
                            qos=run_qos.get(name))
        drv.schedule_arrivals([(lams[name], s["t_run"])],
                              seed=seed * 1000 + k)
        drivers[name] = drv
    loop.run(s["t_run"] + s["drain"])
    per = {name: _workflow_metrics(
        drv, qos_by[name].slo if name in qos_by else None, s["t_run"])
        for name, drv in drivers.items()}
    # egalitarian welfare over per-workflow SLO attainment (met/arrived):
    # normalizing by observed arrivals, not the nominal rate, keeps
    # Poisson undersampling of a light workflow out of the comparison
    welfare = min(m["slo_met"] / max(m["arrived"], 1)
                  for m in per.values())
    return {"per_workflow": per, "fleet_welfare": welfare}


def _alloc_row(a) -> dict:
    return {"replicas": a.replicas, "tp": a.tp, "fraction": a.fraction,
            "chip_class": a.chip_class}


def run_hetero_part(s, seed: int) -> dict:
    wfs = {n: get_workflow(n) for n in s["hetero_lams"]}
    lams = s["hetero_lams"]
    spec = _hetero_spec(s)
    blind_spec = _blend_spec(s)

    t0 = time.perf_counter()
    _, multi_a, qos_a = _plan_fleet(wfs, lams, spec, s, seed)
    aware_plan_s = time.perf_counter() - t0
    allocs_a = {n: r.allocations for n, r in multi_a.per_workflow.items()}
    placement_a = place_fleet(allocs_a, spec)
    aware = _drive_hetero(wfs, placement_a, qos_a, lams, s, seed)

    t0 = time.perf_counter()
    _, multi_b, qos_b = _plan_fleet(wfs, lams, blind_spec, s, seed)
    blind_plan_s = time.perf_counter() - t0
    # strip the blend bindings: the blind plan places class-free on the
    # REAL mixed cluster and runs at whatever class each chip really is
    allocs_b = {
        n: {m: dataclasses.replace(a, chip_class=None)
            for m, a in r.allocations.items()}
        for n, r in multi_b.per_workflow.items()
    }
    blind_placement_error: Optional[str] = None
    try:
        placement_b = place_fleet(allocs_b, spec)
        blind = _drive_hetero(wfs, placement_b, qos_b, lams, s, seed)
    except PlacementError as e:
        blind_placement_error = str(e)
        blind = {"per_workflow": {}, "fleet_welfare": 0.0}

    table = spec.chip_table()

    def _landed(placement) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for inst in placement.instances:
            cls = table[inst.chips[0]][2]
            out[cls] = out.get(cls, 0) + 1
        return out

    return {
        "cluster": {
            "host_groups": [{"chip_class": cls, "num_hosts": n,
                             "chips_per_host": c}
                            for cls, n, c in s["groups"]],
            "num_chips": spec.num_chips,
            "classes": list(spec.classes()),
        },
        "lam_targets": lams,
        "class_aware": {
            "plan_time_s": aware_plan_s,
            "planned_welfare": multi_a.welfare,
            "allocations": {
                n: {m: _alloc_row(a) for m, a in allocs.items()}
                for n, allocs in allocs_a.items()},
            "instances_by_class": _landed(placement_a),
            **aware,
        },
        "class_blind": {
            "plan_time_s": blind_plan_s,
            "planned_welfare": multi_b.welfare,
            "blend_class": {
                "hbm_gib": hw.chip_class("hetero-blend").hbm_bytes / 2**30,
                "peak_tflops": hw.chip_class(
                    "hetero-blend").peak_flops_bf16 / 1e12,
            },
            "allocations": {
                n: {m: _alloc_row(a) for m, a in allocs.items()}
                for n, allocs in allocs_b.items()},
            "instances_by_class": (_landed(placement_b)
                                   if blind_placement_error is None else {}),
            "placement_error": blind_placement_error,
            **blind,
        },
    }


# ---------------------------------------------------------------------------
# Part B: JIT substitution under an overload burst
# ---------------------------------------------------------------------------

_SUB_KEY = "~sub:{}"  # router-dict key for a substitute tenant route


def _substitute_maps(wfs, tenants) -> Dict[str, Dict[str, str]]:
    """workflow -> local llm name -> substitute tenant's canonical id
    (only for substitutes that actually have deployed replicas)."""
    out: Dict[str, Dict[str, str]] = {}
    for name, wf in wfs.items():
        m = {}
        for local, cfg in wf.llms.items():
            sub = cfg.substitute
            if sub and sub != cfg.name and sub in tenants:
                m[local] = sub
        if m:
            out[name] = m
    return out


def _drive_sub(wfs, qos_by, pooled, s, seed: int, *,
               substitution: bool) -> dict:
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop,
                             discipline="priority",
                             members=pooled.members, routing=pooled.routing)
    per_wf = pooled_fleet_routers(tenants, pooled.members, pooled.routing)
    sub_maps: Dict[str, Dict[str, str]] = {}
    sub_routers: Dict[str, Dict[str, object]] = {}
    if substitution:
        for name, m in _substitute_maps(wfs, tenants).items():
            keyed = {}
            for local, sub in m.items():
                key = _SUB_KEY.format(sub)
                per_wf[name][key] = tenants[sub]
                keyed[local] = key
                sub_routers.setdefault(name, {})[local] = tenants[sub]
            sub_maps[name] = keyed
    run_qos = {n: WorkflowQoS(slo=q.slo, work=q.work)
               for n, q in qos_by.items()}
    ctrl = fleet_admission(run_qos, per_wf,
                           substitutes=sub_routers if substitution else None)
    drivers: Dict[str, ClusterDriver] = {}
    for k, name in enumerate(sorted(wfs)):
        drv = ClusterDriver(wfs[name], per_wf[name], loop,
                            qos=run_qos.get(name),
                            substitute_map=sub_maps.get(name))
        lam = s["sub_lams"][name]
        factor = s["burst"].get(name, 1.0)
        drv.schedule_arrivals(
            [(lam, s["t_warm"]), (lam * factor, s["t_burst"]),
             (lam, s["t_tail"])],
            seed=seed * 1000 + k)
        drivers[name] = drv
    horizon = s["t_warm"] + s["t_burst"] + s["t_tail"]
    loop.run(horizon + s["sub_drain"])
    per = {name: _workflow_metrics(
        drv, qos_by[name].slo if name in qos_by else None, horizon)
        for name, drv in drivers.items()}
    return {
        "per_workflow": per,
        "total_goodput_rps": sum(m["goodput_rps"] for m in per.values()),
        "controller": ctrl.stats(),
        "substitution_rates": ctrl.substitution_rates(),
        "sub_maps": sub_maps,
    }


def run_substitution_part(s, seed: int) -> dict:
    lams = s["sub_lams"]
    wfs = {n: get_workflow(n) for n in lams}
    spec = cluster_for(s["sub_chips"])

    dep = deploy_multi(list(wfs.values()), spec, lams,
                       scheduler_config=SchedulerConfig(max_tp=2),
                       mode="pooled", n_trace_requests=s["n_trace"],
                       max_profile_groups=s["profile_groups"], seed=seed)
    pooled = dep.schedule.pooled
    qos_by = dep.qos

    shed = _drive_sub(wfs, qos_by, pooled, s, seed, substitution=False)
    sub = _drive_sub(wfs, qos_by, pooled, s, seed, substitution=True)

    # per-SLO-class substitution rates
    by_class: Dict[str, dict] = {}
    for name, m in sub["per_workflow"].items():
        cls = m["slo_class"] or "unclassified"
        row = by_class.setdefault(cls, {"arrived": 0, "substituted": 0})
        row["arrived"] += m["arrived"]
        row["substituted"] += m["substituted"]
    for row in by_class.values():
        row["substitution_rate"] = (row["substituted"] / row["arrived"]
                                    if row["arrived"] else 0.0)

    # feed observed rates back into the merged pipeline's attribution:
    # per-tenant rate = substituted/arrived over the workflows whose
    # substitute map moves calls off that tenant
    tenant_rates: Dict[str, float] = {}
    for cid in pooled.allocations:
        arrived = substituted = 0
        for name, m in sub["sub_maps"].items():
            moved = {wfs[name].llms[local].name for local in m}
            if cid in moved:
                arrived += sub["per_workflow"][name]["arrived"]
                substituted += sub["per_workflow"][name]["substituted"]
        if arrived:
            tenant_rates[cid] = substituted / arrived
    merged = merge_pipelines(
        {n: dep.deployments[n].pipeline for n in wfs}, lams)
    resub = merged.with_substitution(tenant_rates)
    share_shift = {
        cid: {
            "before_n": merged.stages[cid].n if cid in merged.stages else 0.0,
            "after_n": resub.stages[cid].n if cid in resub.stages else 0.0,
        }
        for cid in sorted(set(merged.stages) | set(resub.stages))
    }

    return {
        "cluster_chips": spec.num_chips,
        "lam_targets": lams,
        "burst": s["burst"],
        "phases_s": {"warm": s["t_warm"], "burst": s["t_burst"],
                     "tail": s["t_tail"]},
        "tenants": {cid: _alloc_row(a)
                    for cid, a in pooled.allocations.items()},
        "substitute_tiers": {
            name: {local: wfs[name].llms[local].substitute
                   for local in m}
            for name, m in _substitute_maps(
                wfs, pooled.allocations).items()},
        "shed_only": {k: v for k, v in shed.items() if k != "sub_maps"},
        "substitution": {k: v for k, v in sub.items() if k != "sub_maps"},
        "per_class_substitution": by_class,
        "goodput_recovered_rps": (sub["total_goodput_rps"]
                                  - shed["total_goodput_rps"]),
        "attribution_share_shift": share_shift,
    }


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def run(quick: bool = False, smoke: bool = False, seed: int = 0, out=None):
    t_run0 = time.perf_counter()
    s = _settings(quick, smoke)

    hetero = run_hetero_part(s, seed)
    substitution = run_substitution_part(s, seed)

    acceptance = {
        "class_aware_beats_class_blind": (
            hetero["class_aware"]["fleet_welfare"]
            > hetero["class_blind"]["fleet_welfare"]),
        "substitution_recovers_goodput": (
            substitution["goodput_recovered_rps"] > 0.0),
        "substitution_observed": any(
            m["substituted"] > 0
            for m in substitution["substitution"]["per_workflow"].values()),
        "substitution_never_upgrades_class": all(
            m["slo_class"] == substitution["shed_only"]
            ["per_workflow"][n]["slo_class"]
            for n, m in substitution["substitution"]
            ["per_workflow"].items()),
    }

    doc = {
        "benchmark": "hetero_serving",
        "mode": s["mode"],
        "seed": seed,
        "config": {
            "hetero_groups": [list(g) for g in s["groups"]],
            "hetero_lams": s["hetero_lams"],
            "sub_chips": s["sub_chips"],
            "sub_lams": s["sub_lams"],
            "burst": s["burst"],
        },
        "hetero": hetero,
        "substitution": substitution,
        "acceptance": acceptance,
    }
    doc["meta"] = run_metadata(seed=seed,
                               config={"quick": quick, "smoke": smoke},
                               started=t_run0)
    text = json.dumps(doc, indent=2)
    print(text)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true", help="full-size sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (schema-identical)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for all phases")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
