"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size sweeps (default: quick)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_ablation, bench_combined, bench_drift,
                            bench_e2e, bench_hetero, bench_kernels,
                            bench_multi_workflow, bench_multiplexing,
                            bench_obs, bench_pipeline_accuracy,
                            bench_placement, bench_prefix, bench_qos,
                            bench_roofline, bench_scale, bench_scheduler,
                            bench_stability, bench_traffic,
                            bench_workflow_aware)

    sections = [
        ("fig3_stability", bench_stability),
        ("fig6_e2e_vs_autoscaler", bench_e2e),
        ("fig7_vs_multiplexing", bench_multiplexing),
        ("fig8_vs_workflow_aware", bench_workflow_aware),
        ("fig9_combined_workflows", bench_combined),
        ("fig10_ablation", bench_ablation),
        ("fig11_scheduler_search", bench_scheduler),
        ("multi_workflow_fleet", bench_multi_workflow),
        ("drift_rescheduling", bench_drift),
        ("qos_scheduling", bench_qos),
        ("prefix_serving", bench_prefix),
        ("hetero_serving", bench_hetero),
        ("placement_aware", bench_placement),
        ("scale_event_core", bench_scale),
        ("traffic_replay", bench_traffic),
        ("observability", bench_obs),
        ("pipeline_accuracy", bench_pipeline_accuracy),
        ("kernels", bench_kernels),
        ("roofline", bench_roofline),
    ]
    for name, mod in sections:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            mod.run(quick=quick)
        except Exception as e:  # keep the suite going; failures are visible
            print(f"BENCHMARK FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
        print(f"----- {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
