"""Fig. 9 — combined workflows on 8 chips: one workflow's rate fixed, the
other swept; egalitarian multi-workflow scheduling adapts the split."""
from __future__ import annotations

from benchmarks.common import joint_run
from repro import hw
from repro.core.scepsy import build_pipeline
from repro.core.scheduler import SchedulerConfig, schedule_multi
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER


def run(quick: bool = False):
    spec = hw.PAPER_CLUSTER_8
    pipes, wfs = {}, {}
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        p, _, _ = build_pipeline(wf, n_trace_requests=15, tp_degrees=(1, 2),
                                 max_profile_groups=12)
        pipes[wf.name] = p
        wfs[wf.name] = wf
    n_req = 25 if quick else 60
    print("fixed_wf,fixed_rate,swept_wf,swept_rate,"
          "beam_mean_lat_s,rag_mean_lat_s,chip_split")
    results = []
    scenarios = [
        ("beam_search", 0.2, "rag_reranker", (1.0, 3.0, 5.0)),
        ("rag_reranker", 3.0, "beam_search", (0.1, 0.25, 0.4)),
    ]
    for fixed, frate, swept, srates in scenarios:
        for sr in srates:
            lams = {fixed: frate, swept: sr}
            try:
                res = schedule_multi(pipes, spec, lams,
                                     SchedulerConfig(max_tp=2), split_step=2)
            except RuntimeError:
                continue
            wf_allocs = [(wfs[n], res.per_workflow[n].allocations)
                         for n in pipes]
            lats = {n: m["mean_latency_s"]
                    for n, m in joint_run(wf_allocs, lams, n_req).items()}
            print(f"{fixed},{frate},{swept},{sr},"
                  f"{lats['beam_search']:.2f},{lats['rag_reranker']:.2f},"
                  f"\"{res.chip_split}\"")
            results.append((fixed, frate, swept, sr, lats))
    return results


if __name__ == "__main__":
    run(quick=True)
