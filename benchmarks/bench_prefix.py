"""Prefix-serving benchmark: measured KV reuse, affinity routing, QoS
preemption.

Three measured sections over the react_agent/debate fleet (the two
workloads whose calls re-send a growing conversation prefix):

* ``savings`` — the fleet runs twice, with prefix-affinity routing on
  vs off (same arrivals, same replicas); the metric is prefill tokens
  the engines actually computed.  Affinity routes a call to the replica
  holding the longest live prefix of its prompt, so the shared prefix
  is served from the radix cache instead of recomputed.
* ``exactness`` — single replica per stage with the default (ample) KV
  budget: the simulator's per-request measured cached-prefix tokens
  must equal the driver's ground-truth shared-prefix tokens *exactly*
  (no eviction occurs, parent chains are the only sharing).  A
  tiny-budget variant is reported alongside to show eviction honesty
  (measured < truth once KV is dropped).
* ``preemption`` — a bench_qos-style overload burst on a pooled
  replica set (react_agent = gold and debate = bronze share the
  LLAMA-3.2-1B stage): the bronze arrival rate multiplies for a burst
  window while gold stays planned, under priority queues, with engine
  preemption off vs on.  Preemption lets a gold prefill bump a bronze
  decode out of a full batch, so gold p99 must be no worse; every
  preemption event is checked for priority inversion.

``acceptance`` gates the ISSUE criteria: >= 30% prefill-token savings
with affinity on, exact cached-prefix accounting under no eviction, gold
p99 no worse with preemption, and no priority-inverting preemption.

JSON schema is documented in benchmarks/README.md; ``--smoke`` is the
tiny-config mode CI runs (schema-identical, small fleet/horizons).
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Dict, List, Optional

from benchmarks.common import run_metadata
from repro.qos.policy import make_policy
from repro.qos.slo import BRONZE, GOLD, WorkflowQoS, WorkModel
from repro.serving.simulator import EngineSim, EventLoop, Router
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver, trace_workflow

FLEET = ("react_agent", "debate")


def _settings(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {
            "mode": "smoke",
            "replicas": 3,
            "lam": {"react_agent": 2.0, "debate": 2.5},
            "n_requests": {"react_agent": 40, "debate": 40},
            "exact_n": 10,
            "burst_factor": 8.0,
            "t_warm": 20.0,
            "t_burst": 60.0,
            "t_tail": 20.0,
            "drain": 600.0,
            "pool_replicas": 2,
            "pool_max_batch": 8,
        }
    return {
        "mode": "quick" if quick else "full",
        "replicas": 4,
        "lam": {"react_agent": 2.5, "debate": 3.0},
        "n_requests": {"react_agent": 80 if quick else 200,
                       "debate": 80 if quick else 200},
        "exact_n": 16 if quick else 40,
        "burst_factor": 8.0,
        "t_warm": 30.0,
        "t_burst": 90.0 if quick else 240.0,
        "t_tail": 30.0,
        "drain": 1200.0,
        "pool_replicas": 3,
        "pool_max_batch": 8,
    }


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(int(q * len(xs)), len(xs) - 1)]


# ---------------------------------------------------------------------------
# affinity on/off prefill-token savings
# ---------------------------------------------------------------------------


def _private_fleet(wfs, loop: EventLoop, *, replicas: int,
                   affinity: bool,
                   kv_override: Optional[int] = None):
    """Per-workflow, per-stage private replica sets (one Router each)."""
    routers: Dict[str, Dict[str, Router]] = {}
    engines: List[EngineSim] = []
    for name, wf in wfs.items():
        routers[name] = {}
        for llm, cfg in wf.llms.items():
            engs = [EngineSim(cfg, loop, name=f"{name}/{llm}/{r}",
                              kv_capacity_override=kv_override)
                    for r in range(replicas)]
            engines.extend(engs)
            routers[name][llm] = Router(engs, affinity=affinity)
    return routers, engines


def _run_fleet(wfs, s, seed: int, *, affinity: bool, replicas: int,
               kv_override: Optional[int] = None):
    loop = EventLoop()
    routers, engines = _private_fleet(
        wfs, loop, replicas=replicas, affinity=affinity,
        kv_override=kv_override)
    # schedule every workflow's Poisson arrivals on the shared loop,
    # then run once (identical arrivals for the on/off comparison)
    drivers = {}
    for k, name in enumerate(sorted(wfs)):
        drv = ClusterDriver(wfs[name], routers[name], loop)
        drv.schedule_open_loop(s["lam"][name], s["n_requests"][name],
                               seed=seed * 1000 + k)
        drivers[name] = drv
    loop.run(1e7)
    return drivers, engines


def _savings(wfs, s, seed: int) -> dict:
    out = {}
    totals = {}
    for affinity in (True, False):
        drivers, engines = _run_fleet(wfs, s, seed, affinity=affinity,
                                      replicas=s["replicas"])
        key = "affinity_on" if affinity else "affinity_off"
        per_wf = {}
        for name, drv in drivers.items():
            done = [r for r in drv.records if r.done >= 0]
            per_wf[name] = {
                "completed": len(done),
                "mean_latency_s": statistics.mean(
                    [r.latency for r in done]) if done else 0.0,
            }
        totals[key] = {
            "prefill_tokens": sum(e.prefill_tokens for e in engines),
            "cached_tokens": sum(e.cached_tokens for e in engines),
        }
        out[key] = {"per_workflow": per_wf, **totals[key]}
    on, off = totals["affinity_on"], totals["affinity_off"]
    saved = (1.0 - on["prefill_tokens"] / off["prefill_tokens"]
             if off["prefill_tokens"] else 0.0)
    out["prefill_token_savings"] = saved
    return out


# ---------------------------------------------------------------------------
# cached-prefix exactness (no eviction) + eviction honesty
# ---------------------------------------------------------------------------


def _exactness(wfs, s, seed: int) -> dict:
    out = {}
    for name, wf in wfs.items():
        row = {}
        for label, kv_override in (("no_eviction", None),
                                   ("tiny_budget", 64)):
            loop = EventLoop()
            routers, engines = _private_fleet(
                {name: wf}, loop, replicas=1, affinity=True,
                kv_override=kv_override)
            drv = ClusterDriver(wf, routers[name], loop)
            drv.run_open_loop(s["lam"][name], s["exact_n"],
                              seed=seed + 17, until=1e7)
            reqs = [r for e in engines for r in e.done]
            measured = sum(r.cached_prefix for r in reqs)
            truth = sum(r.true_prefix for r in reqs)
            row[label] = {
                "requests": len(reqs),
                "measured_cached_tokens": measured,
                "true_shared_tokens": truth,
                "exact": measured == truth,
            }
        out[name] = row
    return out


# ---------------------------------------------------------------------------
# preemption under a bench_qos-style burst (pooled gold + bronze stage)
# ---------------------------------------------------------------------------


def _pooled_burst(wfs, s, seed: int, *, preemption: bool) -> dict:
    """react_agent (gold) and debate (bronze) share the LLAMA-3.2-1B
    stage (react's ``summ`` == debate's ``debater`` architecture); the
    bronze rate multiplies during the burst window."""
    loop = EventLoop()
    react, debate = wfs["react_agent"], wfs["debate"]
    shared_cfg = react.llms["summ"]  # == debate.llms["debater"]
    shared = [EngineSim(shared_cfg, loop, name=f"pool/{r}",
                        policy=make_policy("priority"),
                        preemption=preemption,
                        max_batch_override=s["pool_max_batch"])
              for r in range(s["pool_replicas"])]
    pool = Router(shared)
    w = {i: 1.0 for i in range(len(shared))}
    routers = {
        "react_agent": {
            "agent": Router([EngineSim(react.llms["agent"], loop,
                                       name="react/agent/0",
                                       policy=make_policy("priority"))]),
            "summ": pool.view(w),
        },
        "debate": {
            "debater": pool.view(w),
            "judge": Router([EngineSim(debate.llms["judge"], loop,
                                       name="debate/judge/0",
                                       policy=make_policy("priority"))]),
        },
    }
    # absolute SLO targets from unloaded trace latency (cheap, cached by
    # the caller via `bases`)
    qos = {
        "react_agent": WorkflowQoS(
            slo=GOLD.resolve(s["bases"]["react_agent"]),
            work=WorkModel(per_call_s={}, total_s=0.0, serial_s=0.0)),
        "debate": WorkflowQoS(
            slo=BRONZE.resolve(s["bases"]["debate"]),
            work=WorkModel(per_call_s={}, total_s=0.0, serial_s=0.0)),
    }
    drivers = {}
    for k, name in enumerate(sorted(wfs)):
        drv = ClusterDriver(wfs[name], routers[name], loop, qos=qos[name])
        lam = s["lam"][name]
        factor = s["burst_factor"] if name == "debate" else 1.0
        drv.schedule_arrivals(
            [(lam, s["t_warm"]), (lam * factor, s["t_burst"]),
             (lam, s["t_tail"])],
            seed=seed * 1000 + k)
        drivers[name] = drv
    horizon = s["t_warm"] + s["t_burst"] + s["t_tail"]
    loop.run(horizon + s["drain"])

    def metrics(drv):
        done = [r for r in drv.records if r.done >= 0]
        lats = [r.latency for r in done]
        return {
            "arrived": len(drv.records),
            "completed": len(done),
            "p50_latency_s": _percentile(lats, 0.50),
            "p99_latency_s": _percentile(lats, 0.99),
        }

    log = [ev for e in shared for ev in e.preempt_log]
    return {
        "per_workflow": {n: metrics(d) for n, d in drivers.items()},
        "preemptions": len(log),
        "priority_inversions": sum(1 for pw, vw, _ in log if pw <= vw),
    }


def _preemption(wfs, s, seed: int) -> dict:
    bases = {}
    for name in FLEET:
        store = trace_workflow(wfs[name], 6, seed=seed)
        bases[name] = statistics.mean(
            tr.t_end - tr.t_start for tr in store.traces)
    s = dict(s, bases=bases)
    off = _pooled_burst(wfs, s, seed, preemption=False)
    on = _pooled_burst(wfs, s, seed, preemption=True)
    return {
        "slo_targets_s": {n: 2.0 * bases[n] for n in FLEET},
        "preemption_off": off,
        "preemption_on": on,
        "gold_p99_off_s": off["per_workflow"]["react_agent"]["p99_latency_s"],
        "gold_p99_on_s": on["per_workflow"]["react_agent"]["p99_latency_s"],
    }


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def run(quick: bool = False, smoke: bool = False, seed: int = 0, out=None):
    t_run0 = time.perf_counter()
    s = _settings(quick, smoke)
    wfs = {name: get_workflow(name) for name in FLEET}

    savings = _savings(wfs, s, seed)
    exactness = _exactness(wfs, s, seed)
    preemption = _preemption(wfs, s, seed)

    acceptance = {
        "prefill_savings_ge_30pct": savings["prefill_token_savings"] >= 0.30,
        "cached_prefix_exact_no_eviction": all(
            row["no_eviction"]["exact"] for row in exactness.values()),
        "eviction_reduces_hits": all(
            row["tiny_budget"]["measured_cached_tokens"]
            < row["tiny_budget"]["true_shared_tokens"]
            for row in exactness.values()),
        "gold_p99_not_worse_with_preemption": (
            preemption["gold_p99_on_s"]
            <= preemption["gold_p99_off_s"] * (1.0 + 1e-9)),
        "preemptions_never_invert_priority": (
            preemption["preemption_off"]["priority_inversions"] == 0
            and preemption["preemption_on"]["priority_inversions"] == 0),
        "preemption_exercised": (
            preemption["preemption_on"]["preemptions"] > 0),
    }

    doc = {
        "benchmark": "prefix_serving",
        "mode": s["mode"],
        "seed": seed,
        "config": {
            "fleet": list(FLEET),
            "replicas_per_stage": s["replicas"],
            "lam": s["lam"],
            "n_requests": s["n_requests"],
            "burst_factor": s["burst_factor"],
            "phases_s": {"warm": s["t_warm"], "burst": s["t_burst"],
                         "tail": s["t_tail"]},
            "pool": {"replicas": s["pool_replicas"],
                     "max_batch": s["pool_max_batch"]},
        },
        "savings": savings,
        "exactness": exactness,
        "preemption": preemption,
        "acceptance": acceptance,
    }
    doc["meta"] = run_metadata(seed=seed,
                               config={"quick": quick, "smoke": smoke},
                               started=t_run0)
    text = json.dumps(doc, indent=2)
    print(text)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true", help="full-size sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (schema-identical)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for all phases")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
