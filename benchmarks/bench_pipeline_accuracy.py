"""§4 validation — Aggregate LLM Pipeline predictive power: predicted vs
simulated workflow latency and throughput across arrival rates."""
from __future__ import annotations

import statistics

from repro import hw
from repro.core.scepsy import build_pipeline
from repro.core.scheduler import SchedulerConfig, schedule
from repro.serving.deploy import routers_from_allocations
from repro.serving.simulator import EventLoop
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER
from repro.workflows.runtime import ClusterDriver


def run(quick: bool = False):
    n_req = 30 if quick else 80
    spec = hw.PAPER_CLUSTER_8
    print("workflow,rate,pred_latency_s,sim_latency_s,rel_err,"
          "pred_tput,sim_tput")
    results = []
    rates = {"beam_search": (0.15, 0.3, 0.45),
             "rag_reranker": (2.0, 4.0, 6.0)}
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        pipeline, _, _ = build_pipeline(wf, n_trace_requests=20,
                                        tp_degrees=(1, 2),
                                        max_profile_groups=15)
        for rate in rates[wf.name]:
            res = schedule(pipeline, spec, rate, SchedulerConfig(max_tp=2))
            loop = EventLoop()
            routers = routers_from_allocations(wf, res.allocations, loop)
            driver = ClusterDriver(wf, routers, loop)
            recs = driver.run_open_loop(rate, n_req, seed=5)
            recs = [r for r in recs if r.done >= 0]
            sim_lat = statistics.mean(r.latency for r in recs)
            span = max(r.done for r in recs) - min(r.arrival for r in recs)
            sim_tput = len(recs) / span
            pred = res.prediction
            rel = abs(pred.latency - sim_lat) / sim_lat
            print(f"{wf.name},{rate},{pred.latency:.2f},{sim_lat:.2f},"
                  f"{rel:.2f},{pred.max_throughput:.3f},{sim_tput:.3f}")
            results.append((wf.name, rate, pred.latency, sim_lat, rel))
    return results


if __name__ == "__main__":
    run(quick=True)
