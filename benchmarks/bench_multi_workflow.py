"""Fleet scheduling — N agentic workflows share one cluster.

Two sections, one JSON document:

  * ``fleet`` — the PR-1 egalitarian N-way *partitioned* split on 16
    chips, driven jointly on one event loop (kept as the baseline);
  * ``pooled_vs_partitioned`` — the 3-workflow registry fleet
    (react_agent / map_reduce / debate, all serving the same 1B/8B
    configs) scheduled per allocation mode over growing pod sizes:
    partitioned split vs pooled multi-tenant allocation vs auto.  For
    each size the welfare of every mode, the auto pick, and the jointly
    *measured* per-workflow latencies (private replicas for the
    partitioned split, shared tenant replicas + routing tables for the
    pool) are reported.
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import (cluster_for, joint_run, joint_run_pooled,
                               run_metadata)
from repro import hw
from repro.core.scepsy import build_pipeline
from repro.core.scheduler import SchedulerConfig, schedule_multi

from repro.workflows.registry import get_workflow

QUICK_FLEET = (("beam_search", 0.15), ("rag_reranker", 2.0),
               ("react_agent", 0.5))
FULL_FLEET = QUICK_FLEET + (("map_reduce", 0.4),)

# the pooling showcase: every workflow serves the same 1B/8B configs
REGISTRY_FLEET = (("react_agent", 0.5), ("map_reduce", 0.4), ("debate", 0.8))


def _sizes(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {"n_trace": 8, "groups": 6, "n_req": 8}
    return {"n_trace": 12 if quick else 30, "groups": 10 if quick else 30,
            "n_req": 20 if quick else 50}


def _build(fleet, sizes: dict, seed: int):
    pipes, wfs = {}, {}
    for name, _ in fleet:
        wf = get_workflow(name)
        wfs[name] = wf
        pipes[name], _, _ = build_pipeline(
            wf, n_trace_requests=sizes["n_trace"], tp_degrees=(1, 2),
            max_profile_groups=sizes["groups"], seed=seed)
    return pipes, wfs


def _fleet_section(quick: bool, smoke: bool, seed: int):
    fleet = QUICK_FLEET if (quick or smoke) else FULL_FLEET
    spec = hw.PAPER_CLUSTER_16
    sizes = _sizes(quick, smoke)
    n_req = sizes["n_req"]
    lams = dict(fleet)
    pipes, wfs = _build(fleet, sizes, seed)

    t0 = time.perf_counter()
    res = schedule_multi(pipes, spec, lams, SchedulerConfig(max_tp=2),
                         split_step=1)
    sched_time = time.perf_counter() - t0

    measured = joint_run([(wfs[n], res.per_workflow[n].allocations)
                          for n in pipes], lams, n_req, seed=seed)
    return {
        "benchmark": "multi_workflow_fleet",
        "cluster_chips": spec.num_chips,
        "num_workflows": len(fleet),
        "search_mode": res.search_mode,
        "welfare": res.welfare,
        "search_time_s": sched_time,
        "evaluated_splits": res.evaluated_splits,
        "schedule_calls": res.schedule_calls,
        "workflows": [
            {
                "name": n,
                "lam_target": lams[n],
                "chips": res.chip_split[n],
                "utility": res.utilities.get(n),
                "feasible": res.per_workflow[n].feasible,
                "predicted_latency_s": res.per_workflow[n].prediction.latency,
                "measured_mean_latency_s": measured[n]["mean_latency_s"],
                "completed": measured[n]["completed"],
            }
            for n in pipes
        ],
    }


def _pooled_section(quick: bool, smoke: bool, seed: int):
    lams = dict(REGISTRY_FLEET)
    sz = _sizes(quick, smoke)
    n_req = sz["n_req"]
    pipes, wfs = _build(REGISTRY_FLEET, sz, seed)
    cfg = SchedulerConfig(max_tp=2)
    sizes = (16,) if (quick or smoke) else (16, 32, 64)
    rows = []
    for chips in sizes:
        spec = cluster_for(chips)
        per_mode = {}
        for mode in ("partitioned", "pooled", "auto"):
            t0 = time.perf_counter()
            per_mode[mode] = (schedule_multi(pipes, spec, lams, cfg,
                                             mode=mode),
                              time.perf_counter() - t0)
        part, part_t = per_mode["partitioned"]
        pooled, pooled_t = per_mode["pooled"]
        auto, auto_t = per_mode["auto"]
        meas_part = joint_run([(wfs[n], part.per_workflow[n].allocations)
                               for n in pipes], lams, n_req, seed=seed)
        meas_pooled = (joint_run_pooled(wfs, pooled.pooled, lams, n_req,
                                        seed=seed)
                       if pooled.alloc_mode == "pooled" else meas_part)
        rows.append({
            "cluster_chips": chips,
            "welfare_partitioned": part.welfare,
            "welfare_pooled": pooled.welfare,
            "welfare_auto": auto.welfare,
            "auto_picked": auto.alloc_mode,
            "welfare_by_mode": auto.welfare_by_mode,
            "pooled_gain": pooled.welfare - part.welfare,
            "search_time_s": {"partitioned": part_t, "pooled": pooled_t,
                              "auto": auto_t},
            "tenants": ({cid: {"replicas": a.replicas, "tp": a.tp,
                               "fraction": a.fraction}
                         for cid, a in pooled.pooled.allocations.items()}
                        if pooled.pooled else None),
            "chip_share_pooled": (pooled.pooled.chip_share
                                  if pooled.pooled else None),
            "workflows": [
                {
                    "name": n,
                    "lam_target": lams[n],
                    "utility_partitioned": part.utilities.get(n),
                    "utility_pooled": pooled.utilities.get(n),
                    "predicted_latency_partitioned_s":
                        part.per_workflow[n].prediction.latency,
                    "predicted_latency_pooled_s":
                        pooled.per_workflow[n].prediction.latency,
                    "measured_partitioned_s":
                        meas_part[n]["mean_latency_s"],
                    "measured_pooled_s": meas_pooled[n]["mean_latency_s"],
                    "completed_pooled": meas_pooled[n]["completed"],
                }
                for n in pipes
            ],
        })
    return {"benchmark": "pooled_vs_partitioned",
            "fleet": [n for n, _ in REGISTRY_FLEET],
            "clusters": rows}


def run(quick: bool = False, smoke: bool = False, seed: int = 0, out=None):
    t_run0 = time.perf_counter()
    doc = _fleet_section(quick, smoke, seed)
    doc["seed"] = seed
    doc["pooled_vs_partitioned"] = _pooled_section(quick, smoke, seed)
    doc["meta"] = run_metadata(seed=seed,
                               config={"quick": quick, "smoke": smoke},
                               started=t_run0)
    text = json.dumps(doc, indent=2)
    print(text)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(
        description="multi-workflow fleet benchmark (pooled vs partitioned)")
    ap.add_argument("--full", action="store_true", help="full-size sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (schema-identical)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for tracing, profiling and joint runs "
                         "(makes pooled-vs-partitioned sections reproducible)")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
