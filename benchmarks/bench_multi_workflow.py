"""Fleet scheduling — N agentic workflows share one cluster.

Schedules a 3-workflow (quick) or 4-workflow fleet on 16 chips with the
egalitarian N-way split search, then drives all workflows jointly on one
event loop through their scheduled allocations.  Emits one JSON document
per fleet with the chip split, welfare, per-workflow predicted + measured
latency, and search-time/counter diagnostics.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import joint_run
from repro import hw
from repro.core.scepsy import build_pipeline
from repro.core.scheduler import SchedulerConfig, schedule_multi

from repro.workflows.registry import get_workflow

QUICK_FLEET = (("beam_search", 0.15), ("rag_reranker", 2.0),
               ("react_agent", 0.5))
FULL_FLEET = QUICK_FLEET + (("map_reduce", 0.4),)


def run(quick: bool = False):
    fleet = QUICK_FLEET if quick else FULL_FLEET
    spec = hw.PAPER_CLUSTER_16
    n_req = 20 if quick else 50
    lams = dict(fleet)

    pipes, wfs = {}, {}
    for name, _ in fleet:
        wf = get_workflow(name)
        wfs[name] = wf
        pipes[name], _, _ = build_pipeline(
            wf, n_trace_requests=12 if quick else 30, tp_degrees=(1, 2),
            max_profile_groups=10 if quick else 30)

    t0 = time.perf_counter()
    res = schedule_multi(pipes, spec, lams, SchedulerConfig(max_tp=2),
                         split_step=1)
    sched_time = time.perf_counter() - t0

    measured = joint_run([(wfs[n], res.per_workflow[n].allocations)
                          for n in pipes], lams, n_req)
    doc = {
        "benchmark": "multi_workflow_fleet",
        "cluster_chips": spec.num_chips,
        "num_workflows": len(fleet),
        "search_mode": res.search_mode,
        "welfare": res.welfare,
        "search_time_s": sched_time,
        "evaluated_splits": res.evaluated_splits,
        "schedule_calls": res.schedule_calls,
        "workflows": [
            {
                "name": n,
                "lam_target": lams[n],
                "chips": res.chip_split[n],
                "utility": res.utilities.get(n),
                "feasible": res.per_workflow[n].feasible,
                "predicted_latency_s": res.per_workflow[n].prediction.latency,
                "measured_mean_latency_s": measured[n]["mean_latency_s"],
                "completed": measured[n]["completed"],
            }
            for n in pipes
        ],
    }
    print(json.dumps(doc, indent=2))
    return doc


if __name__ == "__main__":
    run(quick=True)
