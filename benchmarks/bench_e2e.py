"""Fig. 6 — Scepsy vs Kubernetes-HPA throughput-latency curves
(RAG+reranker and beam search; 4/8/16 chips)."""
from __future__ import annotations

from repro.core.scepsy import build_pipeline
from benchmarks.common import HEADER, cluster_for, run_k8s, run_scepsy
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER

BASE_RATES = {  # per-4-chips rate grid, scaled linearly with cluster size
    "beam_search": (0.1, 0.2, 0.3, 0.45),
    "rag_reranker": (1.0, 2.5, 4.5, 7.0),
}


def run(quick: bool = False):
    chip_sizes = (4, 8) if quick else (4, 8, 16)
    n_req = 30 if quick else 80
    print(HEADER)
    results = []
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        pipeline, _, _ = build_pipeline(
            wf, n_trace_requests=15 if quick else 40, tp_degrees=(1, 2),
            max_profile_groups=12 if quick else 30)
        for chips in chip_sizes:
            spec = cluster_for(chips)
            for base in BASE_RATES[wf.name]:
                rate = base * chips / 4
                r1 = run_scepsy(wf, pipeline, spec, rate, n_req)
                r2 = run_k8s(wf, spec, rate, n_req)
                print(r1.row())
                print(r2.row())
                results.extend([r1, r2])
    return results


if __name__ == "__main__":
    run(quick=True)
