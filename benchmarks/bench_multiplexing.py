"""Fig. 7 — Scepsy vs Aegaeon-like P/D multiplexing (3P/1D, 2P/2D, 1P/3D)."""
from __future__ import annotations

from repro.core.scepsy import build_pipeline
from benchmarks.common import HEADER, cluster_for, run_aegaeon, run_scepsy
from repro.workflows.beam_search import BEAM_SEARCH
from repro.workflows.rag_reranker import RAG_RERANKER

RATES = {"beam_search": (0.15, 0.3), "rag_reranker": (2.0, 5.0)}


def run(quick: bool = False):
    n_req = 30 if quick else 80
    print(HEADER)
    results = []
    for wf in (BEAM_SEARCH, RAG_RERANKER):
        pipeline, _, _ = build_pipeline(
            wf, n_trace_requests=15 if quick else 40, tp_degrees=(1, 2),
            max_profile_groups=12)
        for chips in (4, 8):
            spec = cluster_for(chips)
            for base in RATES[wf.name]:
                rate = base * chips / 4
                r = run_scepsy(wf, pipeline, spec, rate, n_req)
                print(r.row())
                results.append(r)
                for split in ((3, 1), (2, 2), (1, 3)):
                    r = run_aegaeon(wf, spec, rate, n_req, split=split)
                    print(r.row())
                    results.append(r)
    return results


if __name__ == "__main__":
    run(quick=True)
