"""Drift benchmark: welfare under ramps, static vs the escalation ladder.

A pooled fleet is deployed with ``deploy_multi(..., online=True)`` and
driven through two reproducible drift injections:

* an **arrival-rate ramp** — one workflow's Poisson rate doubles at a
  known simulation time (``ClusterDriver.schedule_arrivals`` segments);
* a **share shift** — :func:`drift_workflow` scales one LLM's output
  lengths, moving its aggregate execution-time share.

The ``detection`` section reports what the :class:`DriftMonitor` saw:
stable-phase false positives (should be none), the typed events, the
detection delay and the rung the ladder recommends.  The ``scenarios``
section measures welfare in the post-ramp regime under five policies —
the pre-drift baseline, a static allocation that never reacts, and each
escalation rung's reaction — and ``reactions`` reports the wall-clock
cost of computing each rung (rung 3 re-runs trace -> profile ->
schedule -> place from scratch, which is what makes the cheaper rungs
worth having).

JSON schema is documented in benchmarks/README.md; ``--smoke`` is the
tiny-config mode CI runs (schema-identical, small fleet/horizons).
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import cluster_for, drive_fleet, joint_run, run_metadata
from repro.core.drift import DriftConfig, DriftMonitor, RateDrift, expectation_from
from repro.core.replan import recommend_rung
from repro.core.scepsy import build_pipeline, deploy_multi
from repro.core.scheduler import SchedulerConfig
from repro.serving.deploy import pooled_fleet_routers, tenant_routers
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver, drift_workflow


def _settings(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {
            "mode": "smoke",
            "lam_targets": {"react_agent": 1.0, "map_reduce": 0.8, "debate": 2.0},
            "chips": 16,
            "n_trace": 8,
            "profile_groups": 6,
            "n_req": 10,
            "t_warm": 60.0,
            "t_obs": 30.0,
            "t_post": 60.0,
        }
    base = {
        "mode": "quick" if quick else "full",
        "lam_targets": {
            "react_agent": 1.5,
            "map_reduce": 1.2,
            "debate": 2.4,
            "beam_search": 0.45,
            "rag_reranker": 6.0,
        },
        "chips": 32,
        "n_trace": 12 if quick else 30,
        "profile_groups": 10 if quick else 30,
        "n_req": 40 if quick else 60,
        "t_warm": 60.0,
        "t_obs": 40.0,
        "t_post": 120.0,
    }
    return base


RAMP_WORKFLOW = "debate"
RAMP_FACTOR = 2.0
SHIFT_LLM = "debater"
SHIFT_SCALE = 1.8


def _event_row(ev) -> dict:
    return {
        "type": type(ev).__name__,
        "workflow": ev.workflow,
        "llm": getattr(ev, "llm", None),
        "magnitude": ev.magnitude,
        "at": ev.at,
    }


def _detection_run(wfs, pooled, monitor, lams, s, *, shift=None, seed=0):
    """Drive the pooled deployment through one drift injection and
    report the monitor's events."""
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop)
    per_wf = pooled_fleet_routers(tenants, pooled.members, pooled.routing)
    t_ramp = s["t_warm"] + s["t_obs"]
    for k, name in enumerate(sorted(wfs)):
        drv = ClusterDriver(wfs[name], per_wf[name], loop, telemetry=monitor)
        dseed = seed * 1000 + k
        if shift is None and name == RAMP_WORKFLOW:
            drv.schedule_arrivals(
                [(lams[name], t_ramp), (lams[name] * RAMP_FACTOR, s["t_post"])],
                seed=dseed,
            )
        elif shift is not None and name == RAMP_WORKFLOW:
            drv.schedule_arrivals([(lams[name], t_ramp)], seed=dseed)
            shifted = ClusterDriver(shift, per_wf[name], loop, telemetry=monitor)
            shifted.schedule_arrivals(
                [(0.0, t_ramp), (lams[name], s["t_post"])],
                seed=dseed,
                rid_start=1_000_000,
            )
        else:
            drv.schedule_arrivals(
                [(lams[name], t_ramp + s["t_post"])], seed=dseed
            )
    loop.schedule(s["t_warm"], monitor.calibrate)
    loop.run(t_ramp)
    stable_events = monitor.poll()
    loop.run(t_ramp + s["t_post"] + 10_000.0)
    post_events = monitor.poll()
    hits = [
        e
        for e in post_events
        if e.workflow == RAMP_WORKFLOW
        and (isinstance(e, RateDrift) if shift is None else True)
    ]
    return {
        "stable_phase_events": [_event_row(e) for e in stable_events],
        "events": [_event_row(e) for e in post_events],
        "detected": bool(hits),
        "detection_delay_s": (hits[0].at - t_ramp) if hits else None,
        "recommended_rung": recommend_rung(post_events),
    }


def _measure(wfs, result_or_pooled, routing, rates, n_req, seed):
    """Simulated per-workflow latency for one scenario."""
    if hasattr(result_or_pooled, "alloc_mode"):  # a MultiScheduleResult
        res = result_or_pooled
        if res.alloc_mode != "pooled":
            return joint_run(
                [(wfs[n], res.per_workflow[n].allocations) for n in wfs],
                rates,
                n_req,
                seed=seed,
            )
        result_or_pooled = res.pooled
        routing = routing or result_or_pooled.routing
    pooled = result_or_pooled
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop)
    per_wf = pooled_fleet_routers(
        tenants, pooled.members, routing or pooled.routing
    )
    drivers = {n: ClusterDriver(wfs[n], per_wf[n], loop) for n in wfs}
    return drive_fleet(drivers, rates, n_req, loop, seed=seed)


def _scenario_row(measured, ref) -> dict:
    utils = {
        n: min(ref[n] / max(m["mean_latency_s"], 1e-9), 1.0)
        for n, m in measured.items()
    }
    return {
        "welfare_measured": min(utils.values()),
        "per_workflow": {
            n: {
                "mean_latency_s": m["mean_latency_s"],
                "completed": m["completed"],
                "utility": utils[n],
            }
            for n, m in measured.items()
        },
    }


def run(quick: bool = False, smoke: bool = False, seed: int = 0, out=None):
    t_run0 = time.perf_counter()
    s = _settings(quick, smoke)
    lams = s["lam_targets"]
    pipes, wfs = {}, {}
    for name in lams:
        wf = get_workflow(name)
        wfs[name] = wf
        pipes[name], _, _ = build_pipeline(
            wf,
            n_trace_requests=s["n_trace"],
            tp_degrees=(1, 2),
            max_profile_groups=s["profile_groups"],
            seed=seed,
        )
    spec = cluster_for(s["chips"])
    cfg = SchedulerConfig(max_tp=2, routing_policy="partition")

    t0 = time.perf_counter()
    dep = deploy_multi(
        list(wfs.values()),
        spec,
        lams,
        pipelines=pipes,
        scheduler_config=cfg,
        mode="pooled",
        online=True,
        n_trace_requests=s["n_trace"],
        max_profile_groups=s["profile_groups"],
        seed=seed,
    )
    plan_time = time.perf_counter() - t0
    pooled0 = dep.schedule.pooled
    ctrl = dep.controller

    # -- detection: rate ramp + share shift (fresh monitors) -------------
    def fresh_monitor():
        return DriftMonitor(
            {n: expectation_from(pipes[n], lams[n]) for n in pipes},
            DriftConfig(),
        )

    shifted = drift_workflow(
        wfs[RAMP_WORKFLOW], output_scale={SHIFT_LLM: SHIFT_SCALE}
    )
    detection = {
        "rate_ramp": _detection_run(
            wfs, pooled0, fresh_monitor(), lams, s, seed=seed
        ),
        "share_shift": _detection_run(
            wfs, pooled0, fresh_monitor(), lams, s, shift=shifted, seed=seed
        ),
    }

    # -- reactions: the three rungs against the ramped targets -----------
    new_lams = dict(lams)
    new_lams[RAMP_WORKFLOW] = lams[RAMP_WORKFLOW] * RAMP_FACTOR
    act1 = ctrl.rebalance(new_lams)
    act2 = ctrl.replan(new_lams, cold=False)
    act3 = ctrl.replan(new_lams, cold=True)
    speedup1 = act3.latency_s / max(act1.latency_s, 1e-9)
    speedup2 = act3.latency_s / max(act2.latency_s, 1e-9)
    reactions = {
        "rung1": {"latency_s": act1.latency_s, "feasible": act1.feasible},
        "rung2": {
            "latency_s": act2.latency_s,
            "feasible": act2.feasible,
            "welfare_predicted": act2.welfare,
            "alloc_mode": act2.result.alloc_mode if act2.result else None,
            "schedule_calls": act2.result.schedule_calls if act2.result else None,
        },
        "rung3": {
            "latency_s": act3.latency_s,
            "feasible": act3.feasible,
            "welfare_predicted": act3.welfare,
            "alloc_mode": act3.result.alloc_mode if act3.result else None,
            "migration": act3.migration.summary() if act3.migration else None,
        },
        "speedup_rung1_vs_cold": speedup1,
        "speedup_rung2_vs_cold": speedup2,
    }

    # -- scenarios: measured welfare in the post-ramp regime -------------
    n_req = s["n_req"]
    meas = {
        "pre": _measure(wfs, pooled0, pooled0.routing, lams, n_req, seed + 1),
        "static": _measure(wfs, pooled0, pooled0.routing, new_lams, n_req, seed + 1),
        "rung1": _measure(wfs, pooled0, act1.routing, new_lams, n_req, seed + 1),
        "rung2": _measure(wfs, act2.result, act2.routing, new_lams, n_req, seed + 1),
        "rung3": _measure(wfs, act3.result, act3.routing, new_lams, n_req, seed + 1),
    }
    ref = {n: meas["pre"][n]["mean_latency_s"] for n in wfs}
    scenarios = {name: _scenario_row(m, ref) for name, m in meas.items()}

    static_w = scenarios["static"]["welfare_measured"]
    doc = {
        "benchmark": "drift_rescheduling",
        "mode": s["mode"],
        "seed": seed,
        "config": {
            "fleet": sorted(wfs),
            "cluster_chips": spec.num_chips,
            "lam_targets": lams,
            "ramp": {"workflow": RAMP_WORKFLOW, "factor": RAMP_FACTOR},
            "share_shift": {
                "workflow": RAMP_WORKFLOW,
                "llm": SHIFT_LLM,
                "output_scale": SHIFT_SCALE,
            },
            "phases_s": {
                "warmup": s["t_warm"],
                "stable": s["t_obs"],
                "post": s["t_post"],
            },
            "n_req": n_req,
        },
        "plan": {
            "alloc_mode": dep.mode,
            "welfare": dep.welfare,
            "plan_time_s": plan_time,
            "tenants": {
                cid: {"replicas": a.replicas, "tp": a.tp, "fraction": a.fraction}
                for cid, a in pooled0.allocations.items()
            },
        },
        "detection": detection,
        "reactions": reactions,
        "scenarios": scenarios,
        "acceptance": {
            "rung1_recovers": scenarios["rung1"]["welfare_measured"] > static_w,
            "rung2_recovers": scenarios["rung2"]["welfare_measured"] > static_w,
            "rung3_recovers": scenarios["rung3"]["welfare_measured"] > static_w,
            "rung1_speedup_ge_5x": speedup1 >= 5.0,
            "rung2_speedup_ge_5x": speedup2 >= 5.0,
        },
    }
    doc["meta"] = run_metadata(
        seed=seed, config={"quick": quick, "smoke": smoke}, started=t_run0
    )
    text = json.dumps(doc, indent=2)
    print(text)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true", help="full-size sweeps")
    ap.add_argument(
        "--smoke", action="store_true", help="tiny CI config (schema-identical)"
    )
    ap.add_argument("--seed", type=int, default=0, help="RNG seed for all phases")
    ap.add_argument("--out", default=None, help="also write the JSON report here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
