"""Placement benchmark: placement-blind vs placement-aware split search.

The partitioned fleet split search historically scored splits purely on
predicted welfare, modeling each workflow's slice as contiguous — so on
deliberately tight or fragmented clusters its best-scoring split can be
*unplaceable* on the real host/ICI-domain topology (sub-chip replicas
that overcommit chips, TP groups with no free hb domain, alignment
padding).  This benchmark deploys the same fleets both ways:

* **blind** — ``SchedulerConfig(placement_aware=False)``: the winner is
  evaluated against BOTH deploy models — the legacy contiguous-slice
  placement (slice-local ``place`` + hb-domain-aligned offsets: the
  placement-blind baseline system as it existed before co-placement)
  and the co-placement probe
  (:func:`repro.core.placement.fleet_feasibility`, what ``deploy_multi``
  runs today).  A plan whose placement fails realizes welfare 0;
* **aware** — ``SchedulerConfig(placement_aware=True)``: every candidate
  split is probed during the search, unplaceable splits rejected, and
  placeable ones scored ``welfare - fragmentation_weight * frag``.

Per scenario the report gives both plans' predicted welfare, placement
feasibility, fragmentation, the legacy contiguous-slice feasibility
(the pre-co-placement model), and — for the aware plan — a simulated
sanity run over routers built from the co-placement itself
(:func:`repro.serving.deploy.fleet_routers_from_placement`).

Acceptance (CI-gated via ``benchmarks.validate`` + the JSON booleans):
the aware search achieves mean realized welfare >= the blind baseline's
with strictly fewer placement failures on at least one tight-cluster
scenario.  JSON schema is documented in benchmarks/README.md;
``--smoke`` is the tiny-config mode CI runs (schema-identical).
"""

from __future__ import annotations

import argparse
import dataclasses as dc
import json
import math
import time

from benchmarks.common import drive_fleet, run_metadata
from repro import hw
from repro.core import placement as pl
from repro.core.scepsy import build_pipeline
from repro.core.scheduler import SchedulerConfig, _subcluster, schedule_multi
from repro.serving.deploy import fleet_routers_from_placement
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver

FRAGMENTATION_WEIGHT = 0.05
WELFARE = "weighted"  # egalitarian min is ~always 0 on deliberately
#                       tight clusters; the weighted mean stays informative


def _settings(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {"mode": "smoke", "n_trace": 8, "profile_groups": 6,
                "n_req": 12}
    return {"mode": "quick" if quick else "full",
            "n_trace": 12 if quick else 30,
            "profile_groups": 10 if quick else 30,
            "n_req": 30 if quick else 60}


def _scenarios(full: bool) -> list:
    """Deliberately tight / fragmented clusters plus one comfortable
    control.  ``tight`` marks the scenarios the acceptance clause
    'strictly fewer failures on at least one tight-cluster scenario'
    quantifies over."""
    out = [
        {
            "name": "tight_8chip",
            "tight": True,
            "spec": hw.ClusterSpec(num_hosts=2, chips_per_host=4,
                                   hb_domain_size=2),
            "lam_targets": {"react_agent": 1.0, "map_reduce": 0.8,
                            "debate": 1.6},
        },
        {
            "name": "tail_5chip",
            "tight": True,
            "spec": hw.ClusterSpec(num_hosts=1, chips_per_host=4,
                                   hb_domain_size=2, tail_chips=1),
            "lam_targets": {"react_agent": 1.0, "debate": 1.2},
        },
        {
            "name": "comfortable_16chip",
            "tight": False,
            "spec": hw.PAPER_CLUSTER_16,
            "lam_targets": {"react_agent": 1.0, "map_reduce": 0.8,
                            "debate": 1.6},
        },
    ]
    if full:
        out.append({
            "name": "fragmented_12chip_dom4",
            "tight": True,
            "spec": hw.ClusterSpec(num_hosts=3, chips_per_host=4,
                                   hb_domain_size=4),
            "lam_targets": {"react_agent": 2.0, "map_reduce": 1.6,
                            "debate": 3.2},
        })
    return out


def _cluster_row(spec: hw.ClusterSpec) -> dict:
    return {"hosts": spec.num_hosts, "chips_per_host": spec.chips_per_host,
            "hb_domain_size": spec.hb_domain_size,
            "tail_chips": spec.tail_chips, "chips": spec.num_chips}


def _contiguous_placeable(result, spec: hw.ClusterSpec) -> bool:
    """Would the legacy contiguous-slice model (slice-local place +
    hb-domain-aligned offsets) have deployed this plan?"""
    try:
        placements = {
            n: pl.place(result.per_workflow[n].allocations,
                        _subcluster(spec, chips))
            for n, chips in result.chip_split.items()
        }
        pl.fleet_offsets(placements, result.chip_split, spec)
        return True
    except pl.PlacementError:
        return False


def _plan_row(result, probe: pl.FeasibilityResult) -> dict:
    return {
        "welfare_predicted": result.welfare,
        "placeable": probe.ok,
        "realized_welfare": result.welfare if probe.ok else 0.0,
        "fragmentation": probe.fragmentation,
        "failed_shape": probe.failed_shape,
        "chip_split": dict(result.chip_split),
        "evaluated_splits": result.evaluated_splits,
        "search_time_s": result.search_time_s,
    }


def _simulate(wfs, placement: pl.Placement, lams, n_req: int,
              seed: int) -> dict:
    """Drive the co-placed fleet through engines built from the placement
    itself; per-workflow completions + mean latency (finite-guarded)."""
    loop = EventLoop()
    routers = fleet_routers_from_placement(wfs, placement, loop)
    drivers = {n: ClusterDriver(wfs[n], routers[n], loop) for n in routers}
    res = drive_fleet(drivers, lams, n_req, loop, seed=seed)
    return {
        n: {
            "completed": r["completed"],
            "mean_latency_s": (r["mean_latency_s"]
                               if math.isfinite(r["mean_latency_s"])
                               else None),
        }
        for n, r in res.items()
    }


def run(quick: bool = True, smoke: bool = False, seed: int = 0,
        out=None) -> dict:
    t_run0 = time.perf_counter()
    s = _settings(quick, smoke)
    scenarios = _scenarios(full=s["mode"] == "full")

    needed = sorted({n for sc in scenarios for n in sc["lam_targets"]})
    wfs, pipes = {}, {}
    for name in needed:
        wf = get_workflow(name)
        wfs[name] = wf
        pipes[name], _, _ = build_pipeline(
            wf, n_trace_requests=s["n_trace"], tp_degrees=(1, 2, 4),
            max_profile_groups=s["profile_groups"], seed=seed)

    rows = []
    for sc in scenarios:
        spec = sc["spec"]
        lams = sc["lam_targets"]
        sub_pipes = {n: pipes[n] for n in lams}
        base = SchedulerConfig(max_tp=spec.hb_domain_size, welfare=WELFARE,
                               fragmentation_weight=FRAGMENTATION_WEIGHT)

        blind = schedule_multi(sub_pipes, spec, lams, base,
                               mode="partitioned")
        blind_probe = pl.fleet_feasibility(
            {n: blind.per_workflow[n].allocations for n in lams}, spec)

        aware = schedule_multi(sub_pipes, spec, lams,
                               dc.replace(base, placement_aware=True),
                               mode="partitioned")
        aware_probe = pl.fleet_feasibility(
            {n: aware.per_workflow[n].allocations for n in lams}, spec)

        contiguous_ok = _contiguous_placeable(blind, spec)
        row = {
            "name": sc["name"],
            "tight": sc["tight"],
            "cluster": _cluster_row(spec),
            "lam_targets": lams,
            "blind": {**_plan_row(blind, blind_probe),
                      "contiguous_slices_placeable": contiguous_ok,
                      "realized_welfare_legacy":
                          blind.welfare if contiguous_ok else 0.0},
            "aware": {**_plan_row(aware, aware_probe),
                      "rejected_splits": aware.placement_rejected_splits,
                      "placement_ok_flag": aware.placement_ok},
        }
        if aware_probe.ok:
            placement = pl.place_fleet(
                {n: aware.per_workflow[n].allocations for n in lams}, spec)
            row["aware"]["fragmentation_placed"] = placement.fragmentation()
            row["measured_aware"] = _simulate(
                wfs, placement, lams, s["n_req"], seed + 1)
        else:
            row["measured_aware"] = None
        rows.append(row)
        print(f"[{sc['name']}] blind: welfare={blind.welfare:.4f} "
              f"placeable={blind_probe.ok}  aware: "
              f"welfare={aware.welfare:.4f} placeable={aware_probe.ok} "
              f"rejected={aware.placement_rejected_splits}", flush=True)

    blind_fail = sum(0 if r["blind"]["placeable"] else 1 for r in rows)
    legacy_fail = sum(
        0 if r["blind"]["contiguous_slices_placeable"] else 1 for r in rows)
    aware_fail = sum(0 if r["aware"]["placeable"] else 1 for r in rows)
    mean_blind = sum(r["blind"]["realized_welfare"] for r in rows) / len(rows)
    mean_legacy = sum(r["blind"]["realized_welfare_legacy"]
                      for r in rows) / len(rows)
    mean_aware = sum(r["aware"]["realized_welfare"] for r in rows) / len(rows)
    # the placement-blind BASELINE is the pre-co-placement system: blind
    # search deployed through contiguous slices.  The aware system must
    # beat it outright on some tight cluster; the co-placement-probe
    # comparison (blind_fail) additionally isolates the search's own
    # contribution when trace/profile fidelity makes blind plans packable
    fewer_on_tight = any(
        r["tight"] and not r["blind"]["contiguous_slices_placeable"]
        and r["aware"]["placeable"] for r in rows)

    doc = {
        "benchmark": "placement_aware",
        "mode": s["mode"],
        "seed": seed,
        "config": {
            "workflows": needed,
            "welfare": WELFARE,
            "fragmentation_weight": FRAGMENTATION_WEIGHT,
            "n_trace": s["n_trace"],
            "profile_groups": s["profile_groups"],
            "n_req": s["n_req"],
            "scenario_names": [sc["name"] for sc in scenarios],
        },
        "scenarios": rows,
        "summary": {
            "scenarios": len(rows),
            "placement_failures_legacy": legacy_fail,
            "placement_failures_blind": blind_fail,
            "placement_failures_aware": aware_fail,
            "mean_realized_welfare_legacy": mean_legacy,
            "mean_realized_welfare_blind": mean_blind,
            "mean_realized_welfare_aware": mean_aware,
        },
        "acceptance": {
            "aware_realized_welfare_ge_blind":
                mean_aware >= max(mean_blind, mean_legacy) - 1e-9,
            "strictly_fewer_failures_on_tight_cluster": fewer_on_tight,
            "aware_all_placeable": aware_fail == 0,
        },
    }
    doc["meta"] = run_metadata(seed=seed,
                               config={"quick": quick, "smoke": smoke},
                               started=t_run0)
    text = json.dumps(doc, indent=2)
    print(text)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true", help="full-size sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (schema-identical)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for tracing/profiling/simulation")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
