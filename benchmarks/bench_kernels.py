"""Kernel-level report: numerical error vs oracle + structural roofline
(VMEM working set per block, arithmetic intensity) for each Pallas kernel.

Wall-clock is meaningless in interpret mode on CPU; the structural terms
are what transfer to the v5e target."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import hw
from repro.kernels.decode_attention import decode_attention_op, decode_attention_ref
from repro.kernels.flash_attention import flash_attention_op, flash_attention_ref
from repro.kernels.rwkv6_scan import wkv6_op, wkv6_scan_ref


def _report(name, err, flops, vmem_bytes, hbm_bytes):
    ai = flops / max(hbm_bytes, 1)
    ridge = hw.PEAK_FLOPS_BF16 / hw.HBM_BW
    bound = "compute" if ai > ridge else "memory"
    print(f"{name},{err:.2e},{flops:.3e},{vmem_bytes/1024:.0f},"
          f"{ai:.1f},{bound}")
    return dict(name=name, err=err, flops=flops, vmem=vmem_bytes, ai=ai)


def run(quick: bool = False):
    print("kernel,max_abs_err,flops,vmem_per_block_KiB,arith_intensity,"
          "bound")
    out = []

    # flash attention: gemma-like block
    B, H, KV, S, D = 1, 4, 2, 512, 128
    ks = jax.random.split(jax.random.key(0), 3)
    q = (jax.random.normal(ks[0], (B, S, H, D)) * 0.5).astype(jnp.bfloat16)
    k = (jax.random.normal(ks[1], (B, S, KV, D)) * 0.5).astype(jnp.bfloat16)
    v = (jax.random.normal(ks[2], (B, S, KV, D)) * 0.5).astype(jnp.bfloat16)
    o = flash_attention_op(q, k, v, block_q=128, block_kv=128)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - ref.astype(jnp.float32))))
    flops = 4.0 * B * H * D * S * S / 2  # causal
    vmem = (128 * D + 2 * 128 * D) * 2 + 128 * D * 4  # q + k + v + acc
    hbm = (B * S * H * D + 2 * B * S * KV * D) * 2 * (S // 128) / 2
    out.append(_report("flash_attention", err, flops, vmem, hbm))

    # decode attention: glm4-like extreme GQA
    B, H, KV, D, Smax = 4, 32, 2, 128, 4096
    q1 = (jax.random.normal(ks[0], (B, H, D)) * 0.5).astype(jnp.bfloat16)
    kc = (jax.random.normal(ks[1], (B, KV, Smax, D)) * 0.5).astype(jnp.bfloat16)
    vc = (jax.random.normal(ks[2], (B, KV, Smax, D)) * 0.5).astype(jnp.bfloat16)
    o = decode_attention_op(q1, kc, vc, jnp.asarray(Smax), block_s=512)
    ref = decode_attention_ref(q1.reshape(B, KV, H // KV, D), kc, vc,
                               Smax).reshape(B, H, D)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - ref.astype(jnp.float32))))
    flops = 4.0 * B * H * D * Smax
    vmem = (16 * D + 2 * 512 * D) * 2 + 16 * D * 4
    hbm = 2 * B * KV * Smax * D * 2  # KV stream dominates
    out.append(_report("decode_attention(gqa16)", err, flops, vmem, hbm))

    # rwkv6 scan
    B, Hh, S, D = 1, 4, 256, 64
    ks = jax.random.split(jax.random.key(1), 5)
    r, k2, v2 = (jax.random.normal(ks[i], (B, Hh, S, D)) * 0.5
                 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, Hh, S, D)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (Hh, D)) * 0.2
    s0 = jnp.zeros((B, Hh, D, D), jnp.float32)
    o, s1 = wkv6_op(r, k2, v2, logw, u, s0, chunk=64)
    fl = lambda a: a.reshape(B * Hh, S, D)
    ref, _ = wkv6_scan_ref(fl(r), fl(k2), fl(v2), fl(logw), u,
                           s0.reshape(B * Hh, D, D), num_heads=Hh)
    err = float(jnp.max(jnp.abs(o - ref.reshape(B, Hh, S, D))))
    C = 64
    flops = B * Hh * (S / C) * (2 * C * D * D * 3 + C * C * D * 3)
    vmem = (4 * C * D) * 4 + D * D * 4  # r,k,v,logw chunks + state
    hbm = 4 * B * Hh * S * D * 4
    out.append(_report("rwkv6_scan", err, flops, vmem, hbm))
    return out


if __name__ == "__main__":
    run()
