"""QoS benchmark: SLO-aware scheduling under an overload burst.

The pooled registry fleet (react_agent = gold, map_reduce = silver,
debate = bronze) is deployed once, then driven through the same
reproducible overload burst — the batch-style workloads' Poisson rates
multiply for a window while the interactive gold class stays at its
planned rate — under each queue discipline:

* ``fifo`` — the seed engines' arrival-order queues (the baseline);
* ``priority`` — workflow-aware urgency (deadline slack minus the
  aggregate pipeline's remaining-work estimate), so nearly-finished
  gold requests jump the burst;
* ``wfq`` — deficit round robin over tenants with routing-weight
  shares, isolating pooled tenants from each other's bursts;
* ``priority+admission`` — priority queues plus the cluster-front
  admission controller (sheddable classes are rejected/degraded when
  the predicted delay blows their SLO).

The ``disciplines`` section reports per-class p50/p99 latency, SLO
violations and goodput (SLO-met completions per second); the
``fairness`` section checks wfq's served-token shares on every *shared*
tenant against the demand-aware routing-weight entitlement (weighted
max-min water-filling over the burst window); ``admission`` reports the
shed accounting.  ``acceptance`` asserts the ISSUE criteria: priority
and wfq beat fifo on gold-class p99 at equal-or-better total goodput,
and wfq keeps every backlogged pooled tenant within 10% of its
entitled share.

JSON schema is documented in benchmarks/README.md; ``--smoke`` is the
tiny-config mode CI runs (schema-identical, small fleet/horizons).
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Dict, List

from benchmarks.common import cluster_for, run_metadata
from repro.core.scepsy import deploy_multi
from repro.core.scheduler import SchedulerConfig
from repro.qos.admission import fleet_admission
from repro.qos.policy import request_cost
from repro.qos.slo import WorkflowQoS
from repro.serving.deploy import pooled_fleet_routers, tenant_routers
from repro.serving.simulator import EventLoop
from repro.workflows.registry import get_workflow
from repro.workflows.runtime import ClusterDriver

DISCIPLINES = ("fifo", "priority", "wfq")


def _settings(quick: bool, smoke: bool) -> dict:
    if smoke:
        return {
            "mode": "smoke",
            "lam_targets": {"react_agent": 1.0, "map_reduce": 0.8,
                            "debate": 1.6},
            "burst": {"map_reduce": 10.0, "debate": 12.0},
            "chips": 8,
            "n_trace": 8,
            "profile_groups": 6,
            "t_warm": 30.0,
            "t_burst": 90.0,
            "t_tail": 30.0,
            "drain": 600.0,
        }
    return {
        "mode": "quick" if quick else "full",
        "lam_targets": {"react_agent": 1.5, "map_reduce": 1.2,
                        "debate": 2.4},
        "burst": {"map_reduce": 10.0, "debate": 12.0},
        "chips": 16,
        "n_trace": 12 if quick else 30,
        "profile_groups": 10 if quick else 30,
        "t_warm": 40.0,
        "t_burst": 150.0 if quick else 300.0,
        "t_tail": 40.0,
        "drain": 1200.0,
    }


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(int(q * len(xs)), len(xs) - 1)]


# ---------------------------------------------------------------------------
# one measured run
# ---------------------------------------------------------------------------


def _drive(disc: str, wfs, qos_by, pooled, s, seed: int, *,
           admission: bool = False) -> dict:
    """Deploy the shared tenant replicas under one queue discipline and
    drive the whole fleet through the burst."""
    loop = EventLoop()
    tenants = tenant_routers(pooled.allocations, pooled.cfgs, loop,
                             discipline=disc, members=pooled.members,
                             routing=pooled.routing)
    per_wf = pooled_fleet_routers(tenants, pooled.members, pooled.routing)
    ctrl = None
    run_qos = {
        name: WorkflowQoS(slo=q.slo, work=q.work)
        for name, q in qos_by.items()
    }
    if admission:
        ctrl = fleet_admission(run_qos, per_wf)
    drivers: Dict[str, ClusterDriver] = {}
    for k, name in enumerate(sorted(wfs)):
        drv = ClusterDriver(wfs[name], per_wf[name], loop,
                            qos=run_qos.get(name))
        lam = s["lam_targets"][name]
        factor = s["burst"].get(name, 1.0)
        drv.schedule_arrivals(
            [(lam, s["t_warm"]), (lam * factor, s["t_burst"]),
             (lam, s["t_tail"])],
            seed=seed * 1000 + k)
        drivers[name] = drv
    horizon = s["t_warm"] + s["t_burst"] + s["t_tail"]
    loop.run(horizon + s["drain"])
    return {
        "drivers": drivers,
        "tenants": tenants,
        "horizon": horizon,
        "admission": ctrl,
    }


def _workflow_metrics(drv: ClusterDriver, slo, horizon: float) -> dict:
    recs = drv.records
    done = [r for r in recs if r.done >= 0]
    lats = [r.latency for r in done]
    met = sum(1 for r in done if r.slo_met)
    return {
        "slo_class": slo.name if slo else "",
        "slo_target_s": slo.latency_target_s if slo else None,
        "arrived": len(recs),
        "completed": len(done),
        "rejected": sum(1 for r in recs if r.rejected),
        "degraded": sum(1 for r in recs if r.degraded),
        "slo_met": met,
        "violations": len(done) - met,
        "goodput_rps": met / horizon,
        "mean_latency_s": statistics.mean(lats) if lats else 0.0,
        "p50_latency_s": _percentile(lats, 0.50),
        "p99_latency_s": _percentile(lats, 0.99),
    }


def _by_class(per_wf: Dict[str, dict]) -> Dict[str, dict]:
    out: Dict[str, dict] = {}
    for m in per_wf.values():
        cls = m["slo_class"] or "unclassified"
        row = out.setdefault(cls, {"completed": 0, "slo_met": 0,
                                   "violations": 0, "goodput_rps": 0.0})
        row["completed"] += m["completed"]
        row["slo_met"] += m["slo_met"]
        row["violations"] += m["violations"]
        row["goodput_rps"] += m["goodput_rps"]
    return out


# ---------------------------------------------------------------------------
# wfq fairness: served-token shares vs demand-aware entitlement
# ---------------------------------------------------------------------------


def _waterfill(demands: Dict[str, float], weights: Dict[str, float],
               capacity: float) -> Dict[str, float]:
    """Weighted max-min entitlement: demand-limited tenants get their
    demand, the surplus recycles to the still-backlogged ones."""
    entitled = {w: 0.0 for w in demands}
    remaining = dict(demands)
    cap = min(capacity, sum(demands.values()))
    active = set(demands)
    while active and cap > 1e-9:
        total_w = sum(weights[w] for w in active)
        share = {w: cap * weights[w] / total_w for w in active}
        limited = {w for w in active if remaining[w] <= share[w] + 1e-9}
        if not limited:
            for w in active:
                entitled[w] += share[w]
            cap = 0.0
            break
        for w in limited:
            entitled[w] += remaining[w]
            cap -= remaining[w]
            remaining[w] = 0.0
            active.discard(w)
    return entitled


def _fairness(run: dict, pooled, s) -> Dict[str, dict]:
    """Per shared tenant: measured served-token share per member
    workflow over the burst window vs its water-filled entitlement."""
    t0 = s["t_warm"]
    t1 = s["t_warm"] + s["t_burst"]
    out: Dict[str, dict] = {}
    for cid, mem in pooled.members.items():
        members = sorted({w for w, _ in mem})
        if len(members) < 2:
            continue  # private tenant: fairness is trivial
        engines = run["tenants"][cid].replicas
        served = {w: 0.0 for w in members}
        demand = {w: 0.0 for w in members}
        for eng in engines:
            live = list(eng.done) + list(eng.waiting) + list(eng.running)
            for r in live:
                w = r.qos.tenant if r.qos is not None else ""
                if w not in served:
                    continue
                cost = request_cost(r)
                if r.t_done >= 0 and t0 <= r.t_done <= t1:
                    served[w] += cost
                # offered into the window: arrived before it closed and
                # not finished before it opened
                if r.arrival <= t1 and not (0 <= r.t_done < t0):
                    demand[w] += cost
        capacity = sum(served.values())
        # routing-weight shares: each member's summed weight over the
        # tenant's replicas, normalized
        wsum = {w: 0.0 for w in members}
        for workflow, llm in mem:
            for _, wt in pooled.routing.get(workflow, {}).get(llm, {}).items():
                wsum[workflow] += wt
        total = sum(wsum.values()) or 1.0
        weights = {w: wsum[w] / total for w in members}
        entitled = _waterfill(demand, weights, capacity)
        rows = {}
        for w in members:
            share = served[w] / capacity if capacity > 0 else 0.0
            ent_share = entitled[w] / capacity if capacity > 0 else 0.0
            dev = (abs(share - ent_share) / ent_share
                   if ent_share > 0 else 0.0)
            rows[w] = {
                "routing_weight_share": weights[w],
                "demand_tokens": demand[w],
                "served_tokens": served[w],
                "served_share": share,
                "entitled_share": ent_share,
                "relative_deviation": dev,
            }
        out[cid] = {
            "members": rows,
            "capacity_tokens": capacity,
            "max_relative_deviation": max(
                r["relative_deviation"] for r in rows.values()),
        }
    return out


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


def run(quick: bool = False, smoke: bool = False, seed: int = 0, out=None):
    t_run0 = time.perf_counter()
    s = _settings(quick, smoke)
    lams = s["lam_targets"]
    wfs = {name: get_workflow(name) for name in lams}
    spec = cluster_for(s["chips"])
    cfg = SchedulerConfig(max_tp=2)

    t0 = time.perf_counter()
    dep = deploy_multi(
        list(wfs.values()), spec, lams,
        scheduler_config=cfg, mode="pooled",
        n_trace_requests=s["n_trace"],
        max_profile_groups=s["profile_groups"], seed=seed)
    plan_time = time.perf_counter() - t0
    pooled = dep.schedule.pooled
    qos_by = dep.qos

    disciplines = {}
    fairness = {}
    for disc in DISCIPLINES:
        r = _drive(disc, wfs, qos_by, pooled, s, seed)
        per_wf = {
            name: _workflow_metrics(
                drv, qos_by[name].slo if name in qos_by else None,
                r["horizon"])
            for name, drv in r["drivers"].items()
        }
        disciplines[disc] = {
            "per_workflow": per_wf,
            "per_class": _by_class(per_wf),
            "total_goodput_rps": sum(
                m["goodput_rps"] for m in per_wf.values()),
        }
        if disc == "wfq":
            fairness = _fairness(r, pooled, s)

    # priority + cluster-front admission control
    adm_run = _drive("priority", wfs, qos_by, pooled, s, seed,
                     admission=True)
    adm_per_wf = {
        name: _workflow_metrics(
            drv, qos_by[name].slo if name in qos_by else None,
            adm_run["horizon"])
        for name, drv in adm_run["drivers"].items()
    }
    admission = {
        "per_workflow": adm_per_wf,
        "per_class": _by_class(adm_per_wf),
        "total_goodput_rps": sum(
            m["goodput_rps"] for m in adm_per_wf.values()),
        "controller": adm_run["admission"].stats(),
    }

    gold = [n for n in wfs
            if n in qos_by and qos_by[n].slo.name == "gold"]

    def gold_p99(section):
        vals = [section["per_workflow"][n]["p99_latency_s"] for n in gold]
        return max(vals) if vals else 0.0

    p99 = {d: gold_p99(disciplines[d]) for d in DISCIPLINES}
    goodput = {d: disciplines[d]["total_goodput_rps"] for d in DISCIPLINES}
    max_dev = max(
        (t["max_relative_deviation"] for t in fairness.values()),
        default=0.0)
    acceptance = {
        "priority_beats_fifo_gold_p99": p99["priority"] < p99["fifo"],
        "wfq_beats_fifo_gold_p99": p99["wfq"] < p99["fifo"],
        "priority_goodput_not_worse": (
            goodput["priority"] >= 0.99 * goodput["fifo"]),
        "wfq_goodput_not_worse": goodput["wfq"] >= 0.99 * goodput["fifo"],
        "wfq_tenant_shares_within_10pct": max_dev <= 0.10,
        "admission_sheds_only_sheddable": all(
            m["rejected"] == 0 and m["degraded"] == 0
            for n, m in adm_per_wf.items()
            if n in qos_by and qos_by[n].slo.shed_policy == "never"),
    }

    doc = {
        "benchmark": "qos_scheduling",
        "mode": s["mode"],
        "seed": seed,
        "config": {
            "fleet": {
                name: {
                    "slo_class": qos_by[name].slo.name,
                    "latency_target_s": qos_by[name].slo.latency_target_s,
                    "weight": qos_by[name].slo.weight,
                    "shed_policy": qos_by[name].slo.shed_policy,
                } for name in sorted(wfs) if name in qos_by
            },
            "cluster_chips": spec.num_chips,
            "lam_targets": lams,
            "burst": s["burst"],
            "phases_s": {"warm": s["t_warm"], "burst": s["t_burst"],
                         "tail": s["t_tail"]},
        },
        "plan": {
            "alloc_mode": dep.mode,
            "welfare": dep.welfare,
            "plan_time_s": plan_time,
            "tenants": {
                cid: {"replicas": a.replicas, "tp": a.tp,
                      "fraction": a.fraction}
                for cid, a in pooled.allocations.items()
            },
        },
        "disciplines": disciplines,
        "fairness": fairness,
        "admission": admission,
        "acceptance": acceptance,
    }
    doc["meta"] = run_metadata(seed=seed,
                               config={"quick": quick, "smoke": smoke},
                               started=t_run0)
    text = json.dumps(doc, indent=2)
    print(text)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true", help="full-size sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (schema-identical)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for all phases")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report here")
    args = ap.parse_args()
    run(quick=not args.full, smoke=args.smoke, seed=args.seed, out=args.out)


if __name__ == "__main__":
    main()
