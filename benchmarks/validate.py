"""Benchmark-report validator: schema presence + finite metrics.

CI's bench-smoke job runs the benchmarks in ``--smoke`` mode and then
this validator over the emitted JSON reports; a missing section or any
non-finite number (NaN/Infinity) fails the job.

    PYTHONPATH=src python -m benchmarks.validate report_drift.json ...
"""

from __future__ import annotations

import json
import math
import sys
from typing import List

# required top-level keys per report type (the "benchmark" field)
REQUIRED = {
    "drift_rescheduling": (
        "config",
        "plan",
        "detection",
        "reactions",
        "scenarios",
        "acceptance",
    ),
    "multi_workflow_fleet": (
        "welfare",
        "workflows",
        "pooled_vs_partitioned",
    ),
    "qos_scheduling": (
        "config",
        "plan",
        "disciplines",
        "fairness",
        "admission",
        "acceptance",
    ),
    "placement_aware": (
        "config",
        "scenarios",
        "summary",
        "acceptance",
    ),
    "hetero_serving": (
        "config",
        "hetero",
        "substitution",
        "acceptance",
    ),
    "prefix_serving": (
        "config",
        "savings",
        "exactness",
        "preemption",
        "acceptance",
    ),
    "scale_event_core": (
        "config",
        "throughput",
        "memory",
        "sketch",
        "workflows",
        "acceptance",
    ),
    "observability": (
        "config",
        "zero_cost",
        "overhead",
        "accuracy",
        "acceptance",
    ),
    "traffic_replay": (
        "config",
        "generator",
        "sessions",
        "replay",
        "golden",
        "acceptance",
    ),
}

# every report must carry the provenance stamp written by
# benchmarks.common.run_metadata, with at least these keys
META_KEYS = ("seed", "git_sha")


def _walk_finite(node, path: str, errors: List[str]) -> None:
    if isinstance(node, bool) or node is None or isinstance(node, str):
        return
    if isinstance(node, (int, float)):
        if not math.isfinite(node):
            errors.append(f"non-finite metric at {path}: {node!r}")
        return
    if isinstance(node, dict):
        for k, v in node.items():
            _walk_finite(v, f"{path}.{k}", errors)
        return
    if isinstance(node, list):
        for i, v in enumerate(node):
            _walk_finite(v, f"{path}[{i}]", errors)
        return
    errors.append(f"unexpected node type at {path}: {type(node).__name__}")


def validate_report(doc: dict, name: str = "report") -> List[str]:
    """Return a list of problems (empty = valid)."""
    errors: List[str] = []
    kind = doc.get("benchmark")
    if kind not in REQUIRED:
        errors.append(
            f"{name}: unknown or missing 'benchmark' field: {kind!r} "
            f"(known: {sorted(REQUIRED)})"
        )
        return errors
    for key in REQUIRED[kind]:
        if key not in doc:
            errors.append(f"{name}: missing required section {key!r}")
    meta = doc.get("meta")
    if not isinstance(meta, dict):
        errors.append(f"{name}: missing run-metadata stamp 'meta'")
    else:
        for key in META_KEYS:
            if key not in meta:
                errors.append(f"{name}: meta stamp missing {key!r}")
    _walk_finite(doc, name, errors)
    return errors


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.validate report.json ...")
        return 2
    failures = 0
    for path in argv:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            print(f"FAIL {path}: unreadable or invalid JSON ({e})")
            failures += 1
            continue
        errors = validate_report(doc, path)
        if errors:
            failures += 1
            for err in errors:
                print(f"FAIL {err}")
        else:
            print(f"OK   {path} ({doc['benchmark']})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
