#!/usr/bin/env python
"""Render an observability dump or bench report as readable tables.

    PYTHONPATH=src python tools/scepsy_report.py DUMP.json
    PYTHONPATH=src python tools/scepsy_report.py DUMP.json --perfetto out.json
    PYTHONPATH=src python tools/scepsy_report.py report_obs.json

Accepts either a tracer export (``benchmarks.bench_obs --dump`` /
``Tracer.export()``) or a full ``bench_obs`` JSON report (the dump is
embedded per-section there only as aggregates, so the report path
renders the accuracy/overhead/zero-cost summaries instead).
``--perfetto`` converts the dump's sampled traces to Chrome
``trace_event`` JSON for https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import json
import sys


def _table(rows, headers):
    if not rows:
        return ""
    cols = [headers] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in cols) for i in range(len(headers))]
    lines = ["  ".join(h.ljust(w) for h, w in zip(headers, widths)),
             "  ".join("-" * w for w in widths)]
    for r in cols[1:]:
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)


def _fmt(v, nd=4):
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def render_dump(doc: dict) -> str:
    out = ["== sampling =="]
    rows = [(wf, c["seen"], c["sampled"])
            for wf, c in sorted(doc["sampling"]["counts"].items())]
    out.append(_table(rows, ["workflow", "seen", "sampled"]))

    out.append("\n== request latency ==")
    rows = [(wf, m["count"], _fmt(m["mean"]), _fmt(m["p50"]), _fmt(m["p99"]))
            for wf, m in sorted(doc["latency"].items()) if m.get("count")]
    out.append(_table(rows, ["workflow", "n", "mean_s", "p50_s", "p99_s"]))

    out.append("\n== execution shares (busy-seconds) ==")
    rows = [(wf, llm, _fmt(share))
            for wf, row in sorted(doc["shares"].items())
            for llm, share in sorted(row.items(), key=lambda kv: -kv[1])]
    out.append(_table(rows, ["workflow", "llm", "share"]))

    counters = doc["metrics"].get("scepsy_requests_total", {})
    if counters:
        out.append("\n== requests by outcome ==")
        rows = [(s["labels"]["workflow"], s["labels"]["outcome"],
                 int(s["value"])) for s in counters["series"]]
        out.append(_table(sorted(rows), ["workflow", "outcome", "n"]))

    routing = doc["metrics"].get("scepsy_routing_total", {})
    if routing:
        out.append("\n== routing tiers ==")
        rows = [(s["labels"]["tier"], int(s["value"]))
                for s in routing["series"]]
        out.append(_table(sorted(rows), ["tier", "n"]))

    n_traces = len(doc.get("traces", ()))
    n_lines = len(doc.get("exposition", "").splitlines())
    out.append(f"\n{n_traces} sampled traces; "
               f"{n_lines} exposition lines in dump")
    return "\n".join(out)


def render_report(doc: dict) -> str:
    out = [f"== bench_obs report (mode={doc.get('mode')}, "
           f"seed={doc.get('seed')}) =="]
    acc = doc.get("acceptance", {})
    rows = [(k, "PASS" if v else "FAIL") for k, v in acc.items()]
    out.append(_table(rows, ["gate", "status"]))

    ov = doc.get("overhead", {})
    if ov:
        out.append("\n== tracing overhead ==")
        out.append(f"requests: {ov['requests']}  trials: {ov['trials']}  "
                   f"ratio: {_fmt(ov['overhead_ratio'], 3)} "
                   f"(gate <= {ov['gate']})")

    ac = doc.get("accuracy", {})
    if ac:
        out.append("\n== share reconciliation ==")
        rows = []
        for wf in sorted(ac.get("observed_shares", {})):
            obs = ac["observed_shares"][wf]
            exp = ac.get("expected_shares", {}).get(wf, {})
            for llm in sorted(obs):
                rows.append((wf, llm, _fmt(obs[llm]),
                             _fmt(exp.get(llm, float("nan")))))
        out.append(_table(rows, ["workflow", "llm", "observed", "expected"]))
        out.append(f"max relative error: "
                   f"{_fmt(ac.get('share_max_rel_err', float('nan')), 3)} "
                   f"(gate <= {ac.get('share_gate')})")
        out.append("\n== critical path ==")
        rows = []
        for wf, row in sorted(ac.get("critical_path", {}).items()):
            for stage, cell in row["breakdown"].items():
                rows.append((wf, stage, _fmt(cell["seconds"], 2),
                             _fmt(cell["fraction"], 3)))
        out.append(_table(rows, ["workflow", "stage", "seconds", "fraction"]))
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="tracer export dump or bench_obs report")
    ap.add_argument("--perfetto", default=None, metavar="OUT",
                    help="also write Chrome trace_event JSON built from "
                         "the dump's sampled traces")
    args = ap.parse_args(argv)
    with open(args.path) as f:
        doc = json.load(f)

    is_dump = "traces" in doc and "sampling" in doc
    print(render_dump(doc) if is_dump else render_report(doc))

    if args.perfetto:
        if not is_dump:
            print("--perfetto needs a tracer export dump "
                  "(bench_obs --dump)", file=sys.stderr)
            return 2
        from repro.obs import chrome_trace
        trace = chrome_trace(doc["traces"])
        with open(args.perfetto, "w") as f:
            json.dump(trace, f)
        print(f"wrote {len(trace['traceEvents'])} trace events "
              f"to {args.perfetto}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
