#!/usr/bin/env python
"""Documentation consistency checker (CI ``docs`` job).

Scans the repo's user-facing markdown — ``README.md``, everything under
``docs/`` and ``benchmarks/README.md`` — and fails on:

* relative markdown links ``[text](path)`` whose target file does not
  exist (http(s)/mailto links and pure ``#anchors`` are skipped;
  relative targets are resolved against the linking file's directory,
  then against the repo root);
* backtick references to nonexistent code: `` `repro.foo.bar` `` dotted
  module paths that resolve to no module under ``src/`` (attribute
  tails like ``repro.core.placement.place_fleet`` are fine — the
  longest importable prefix is what must exist), and `` `*.py` `` file
  mentions (``benchmarks/bench_placement.py`` or a bare
  ``bench_placement.py``) naming files that exist nowhere in the repo.

Usage::

    python tools/check_docs.py [file-or-dir ...]

Exit code 0 = clean, 1 = problems (each printed as ``FAIL path: ...``).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_TARGETS = ["README.md", "docs", "benchmarks/README.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`]+)`")
MODULE_RE = re.compile(r"^(repro|benchmarks|tools)(\.[A-Za-z_][A-Za-z0-9_]*)+$")
PYFILE_RE = re.compile(r"^[\w./-]+\.py$")


def md_files(targets) -> list:
    out = []
    for t in targets:
        p = REPO / t
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            out.append(p)
        else:
            print(f"FAIL {t}: target does not exist")
            out.append(None)
    return out


def strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks: shell snippets legitimately mention
    paths that only exist at runtime (report_*.json etc.)."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


def module_exists(dotted: str) -> bool:
    """A dotted reference resolves iff its longest existing prefix is a
    module *file* (the rest is then an attribute tail, e.g.
    ``repro.core.placement.place_fleet``) or the FULL path is a
    package/module.  A prefix that is merely a package does NOT excuse
    a nonexistent next segment — ``repro.core.plcement`` (typo) must
    fail even though ``repro.core`` exists.  ``repro.*`` is rooted at
    src/, ``benchmarks.*``/``tools.*`` at the repo root."""
    parts = dotted.split(".")
    roots = {"repro": REPO / "src", "benchmarks": REPO, "tools": REPO}
    base = roots[parts[0]]
    for k in range(len(parts), 1, -1):
        head = base / Path(*parts[:k])
        if head.with_suffix(".py").exists():
            return True  # module file: trailing segments are attributes
        if (head / "__init__.py").exists():
            # a package only resolves the reference when it IS the
            # reference; otherwise the next segment is a missing module
            return k == len(parts)
    return False


def pyfile_exists(ref: str) -> bool:
    if "/" in ref:
        # the docs' established shorthand roots layer paths at
        # src/repro/ (e.g. `core/trace.py`, `qos/slo.py`)
        return any((base / ref).exists()
                   for base in (REPO, REPO / "src", REPO / "src" / "repro"))
    name = Path(ref).name
    return any(REPO.glob(f"**/{name}"))


def check_file(path: Path) -> list:
    errors = []
    rel = path.relative_to(REPO)
    text = path.read_text()
    body = strip_code_blocks(text)

    for m in LINK_RE.finditer(body):
        target = m.group(1).split("#", 1)[0]
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        cand = (path.parent / target, REPO / target)
        if not any(c.exists() for c in cand):
            errors.append(f"{rel}: broken link -> {m.group(1)}")

    for m in CODE_RE.finditer(body):
        tok = m.group(1).strip().rstrip("()")
        if MODULE_RE.match(tok) and not module_exists(tok):
            errors.append(f"{rel}: reference to nonexistent module `{tok}`")
        elif PYFILE_RE.match(tok) and not pyfile_exists(tok):
            errors.append(f"{rel}: reference to nonexistent file `{tok}`")
    return errors


def main(argv) -> int:
    targets = argv or DEFAULT_TARGETS
    files = md_files(targets)
    if None in files:
        return 1
    failures = []
    for f in files:
        failures.extend(check_file(f))
    for err in failures:
        print(f"FAIL {err}")
    if not failures:
        print(f"OK   {len(files)} markdown file(s) checked")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
