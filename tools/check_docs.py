#!/usr/bin/env python
"""Documentation consistency checker (CI ``docs`` job).

Scans the repo's user-facing markdown — ``README.md``, everything under
``docs/`` and ``benchmarks/README.md`` — and fails on:

* relative markdown links ``[text](path)`` whose target file does not
  exist (http(s)/mailto links and pure ``#anchors`` are skipped;
  relative targets are resolved against the linking file's directory,
  then against the repo root);
* backtick references to nonexistent code: `` `repro.foo.bar` `` dotted
  module paths that resolve to no module under ``src/``, and
  `` `*.py` `` file mentions (``benchmarks/bench_placement.py`` or a
  bare ``bench_placement.py``) naming files that exist nowhere in the
  repo;
* attribute tails past a module file (``repro.hw.ChipClass``,
  ``repro.core.placement.place_fleet``) that name no symbol in that
  module.  Verification imports the module when it can and checks the
  full attribute chain; when the import fails (the CI ``docs`` job
  installs no dependencies, so ``import jax`` raises) it falls back to
  an AST scan of the module file's top-level names and checks the
  first tail segment only.

Usage::

    python tools/check_docs.py [file-or-dir ...]

Exit code 0 = clean, 1 = problems (each printed as ``FAIL path: ...``).
"""
from __future__ import annotations

import ast
import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_TARGETS = ["README.md", "docs", "benchmarks/README.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`]+)`")
MODULE_RE = re.compile(r"^(repro|benchmarks|tools)(\.[A-Za-z_][A-Za-z0-9_]*)+$")
PYFILE_RE = re.compile(r"^[\w./-]+\.py$")


def md_files(targets) -> list:
    out = []
    for t in targets:
        p = REPO / t
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            out.append(p)
        else:
            print(f"FAIL {t}: target does not exist")
            out.append(None)
    return out


def strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks: shell snippets legitimately mention
    paths that only exist at runtime (report_*.json etc.)."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


# memoized module state for attribute-tail checks:
# dotted module -> imported module object, or None when unimportable
_IMPORTED: dict = {}
# module file -> set of top-level names (AST fallback)
_TOPLEVEL: dict = {}


def _toplevel_names(pyfile: Path) -> set:
    """Top-level names a module defines, from its AST — functions,
    classes, assignments and imports, including those nested in
    module-level ``if``/``try`` blocks (version/feature gates)."""
    cached = _TOPLEVEL.get(pyfile)
    if cached is not None:
        return cached
    names: set = set()

    def collect(body) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names.add(node.name)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
                    elif isinstance(t, (ast.Tuple, ast.List)):
                        for e in t.elts:
                            if isinstance(e, ast.Name):
                                names.add(e.id)
            elif isinstance(node, ast.AnnAssign):
                if isinstance(node.target, ast.Name):
                    names.add(node.target.id)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for a in node.names:
                    names.add(a.asname or a.name.split(".")[0])
            elif isinstance(node, (ast.If, ast.Try)):
                collect(node.body)
                collect(node.orelse)
                for h in getattr(node, "handlers", []):
                    collect(h.body)
                collect(getattr(node, "finalbody", []))

    try:
        collect(ast.parse(pyfile.read_text()).body)
    except SyntaxError:
        pass  # unparseable module: don't fail the docs for it
    _TOPLEVEL[pyfile] = names
    return names


def _symbols_exist(dotted_module: str, pyfile: Path, tail: list) -> bool:
    """Verify an attribute tail against a module.  Prefer a real import
    (full-chain ``hasattr`` walk); fall back to the AST top-level-name
    scan — first segment only — when the import raises (the docs CI job
    has no third-party deps installed, so ``repro.*`` modules that
    import jax are unimportable there)."""
    if dotted_module not in _IMPORTED:
        for p in (str(REPO / "src"), str(REPO)):
            if p not in sys.path:
                sys.path.append(p)
        try:
            _IMPORTED[dotted_module] = importlib.import_module(dotted_module)
        except Exception:
            _IMPORTED[dotted_module] = None
    mod = _IMPORTED[dotted_module]
    if mod is not None:
        obj = mod
        for seg in tail:
            if not hasattr(obj, seg):
                return False
            obj = getattr(obj, seg)
        return True
    return tail[0] in _toplevel_names(pyfile)


def module_exists(dotted: str) -> bool:
    """A dotted reference resolves iff its longest existing prefix is a
    module *file* whose attribute tail names a real symbol (e.g.
    ``repro.core.placement.place_fleet``) or the FULL path is a
    package/module.  A prefix that is merely a package does NOT excuse
    a nonexistent next segment — ``repro.core.plcement`` (typo) must
    fail even though ``repro.core`` exists.  ``repro.*`` is rooted at
    src/, ``benchmarks.*``/``tools.*`` at the repo root."""
    parts = dotted.split(".")
    roots = {"repro": REPO / "src", "benchmarks": REPO, "tools": REPO}
    base = roots[parts[0]]
    for k in range(len(parts), 1, -1):
        head = base / Path(*parts[:k])
        pyfile = head.with_suffix(".py")
        if pyfile.exists():
            if k == len(parts):
                return True  # the reference IS the module
            return _symbols_exist(".".join(parts[:k]), pyfile, parts[k:])
        if (head / "__init__.py").exists():
            # a package only resolves the reference when it IS the
            # reference; otherwise the next segment is a missing module
            return k == len(parts)
    return False


def pyfile_exists(ref: str) -> bool:
    if "/" in ref:
        # the docs' established shorthand roots layer paths at
        # src/repro/ (e.g. `core/trace.py`, `qos/slo.py`)
        return any((base / ref).exists()
                   for base in (REPO, REPO / "src", REPO / "src" / "repro"))
    name = Path(ref).name
    return any(REPO.glob(f"**/{name}"))


def check_file(path: Path) -> list:
    errors = []
    rel = path.relative_to(REPO)
    text = path.read_text()
    body = strip_code_blocks(text)

    for m in LINK_RE.finditer(body):
        target = m.group(1).split("#", 1)[0]
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        cand = (path.parent / target, REPO / target)
        if not any(c.exists() for c in cand):
            errors.append(f"{rel}: broken link -> {m.group(1)}")

    for m in CODE_RE.finditer(body):
        tok = m.group(1).strip().rstrip("()")
        if MODULE_RE.match(tok) and not module_exists(tok):
            errors.append(
                f"{rel}: reference to nonexistent module or symbol `{tok}`")
        elif PYFILE_RE.match(tok) and not pyfile_exists(tok):
            errors.append(f"{rel}: reference to nonexistent file `{tok}`")
    return errors


def main(argv) -> int:
    targets = argv or DEFAULT_TARGETS
    files = md_files(targets)
    if None in files:
        return 1
    failures = []
    for f in files:
        failures.extend(check_file(f))
    for err in failures:
        print(f"FAIL {err}")
    if not failures:
        print(f"OK   {len(files)} markdown file(s) checked")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
